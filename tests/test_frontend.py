"""Request lifecycle: cancellation safety, deadlines, and the async
streaming front-end (DESIGN.md §13).

The correctness bars:

  * **cancellation is leak-free at every lifecycle state** — QUEUED,
    PREFILLING, DECODING — on both KV layouts and with prefix sharing
    on or off: the cancelled request's pages (including CoW-shared,
    refcount-held ones) come back by the next round boundary, the
    free-list count is fully restored after drain, and ``check()``
    raises no ``PageLeakError``;
  * **survivors are oblivious** — greedy token streams of uncancelled
    requests are bit-identical to a run with no cancellations at all;
  * **deadlines shed work, never corrupt it** — a queued request past
    its deadline is EXPIRED before admission, an active-late one is
    deprioritized and, if evicted, expires instead of restarting;
  * the asyncio front-end streams tokens across rounds, sheds load at
    the intake bound, and reports the same engine ledger.
"""

import asyncio

import numpy as np
import pytest

import jax

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import (
    AsyncFrontend,
    IntakeFullError,
    RequestState,
    SlotServeEngine,
)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_arch("qwen3-14b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_prompts(cfg, lens, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def make_engine(model, prompts, new_tokens, *, layout="paged",
                sharing="off", capacity=2, chunk=6, **kw):
    max_len = max(len(p) for p in prompts) + new_tokens + 1
    params = kw.pop("params")
    return SlotServeEngine(model, params, capacity=capacity,
                           max_len=max_len, decode_chunk=2, seed=0,
                           kv_layout=layout, page_size=8,
                           prefix_sharing=sharing,
                           prefill_chunk_tokens=chunk, **kw)


def drive(eng, prompts, new_tokens, *, arrivals=None, on_round=None,
          max_rounds=500):
    """Serve every prompt to completion, invoking ``on_round(eng,
    reqs)`` after each step (cancellation injection point). Returns the
    request objects in submission order."""
    arr = (np.zeros(len(prompts)) if arrivals is None
           else np.asarray(arrivals))
    reqs, nxt, rounds = [], 0, 0
    while nxt < len(prompts) or eng.queue or eng.active \
            or eng._cancel_pending:
        while nxt < len(prompts) and arr[nxt] <= eng.step_clock:
            reqs.append(eng.submit(prompts[nxt], new_tokens))
            nxt += 1
        if eng.step() == 0 and not eng.queue and nxt < len(prompts):
            eng.step_clock += 1
        if on_round is not None:
            on_round(eng, reqs)
        rounds += 1
        assert rounds < max_rounds, "engine failed to drain"
    return reqs


def assert_no_leaks(eng):
    if eng.kv_layout == "paged":
        eng.pool.pages.check()      # raises PageLeakError on any leak
        assert eng.pool.pages.n_free == eng.pool.pages.num_pages


# ---------------------------------------------------------------------------
# Cancellation safety: every state x layout x sharing
# ---------------------------------------------------------------------------

# (layout, sharing): sharing needs pages to share, so "on" is paged-only
CANCEL_CONFIGS = [("slots", "off"), ("paged", "off"), ("paged", "on")]


@pytest.mark.parametrize("layout,sharing", CANCEL_CONFIGS)
def test_cancel_every_state_survivors_bit_identical(model_and_params,
                                                    layout, sharing):
    cfg, model, params = model_and_params
    # victim prompt 0 repeats prompt 2 so sharing=on actually shares;
    # chunk=6 < len(prompt) so PREFILLING is a reachable state
    prompts = make_prompts(cfg, [14, 5, 9])
    prompts[2] = prompts[0].copy()
    new_tokens = 5

    def run(target_state):
        eng = make_engine(model, prompts, new_tokens, layout=layout,
                          sharing=sharing, params=params)
        victim_cancelled = []

        if target_state is RequestState.QUEUED:
            # cancel before the first round ever runs: no slot, no pages
            reqs = [eng.submit(p, new_tokens) for p in prompts]
            assert eng.cancel(reqs[0].rid)
            victim_cancelled.append(True)
            rounds = 0
            while eng.queue or eng.active:
                eng.step()
                rounds += 1
                assert rounds < 200
            return eng, reqs, victim_cancelled

        def on_round(eng_, reqs):
            if target_state is None or victim_cancelled:
                return
            victim = reqs[0] if reqs else None
            if victim is not None and victim.state is target_state:
                assert eng_.cancel(victim.rid)
                victim_cancelled.append(True)

        eng_reqs = drive(eng, prompts, new_tokens, on_round=on_round)
        return eng, eng_reqs, victim_cancelled

    base_eng, base_reqs, _ = run(None)
    base_streams = [list(r.out_tokens) for r in base_reqs]
    assert all(len(s) == new_tokens for s in base_streams)
    assert_no_leaks(base_eng)

    for state in (RequestState.QUEUED, RequestState.PREFILLING,
                  RequestState.DECODING):
        eng, reqs, cancelled = run(state)
        if not cancelled:
            # one-shot admission (chunk >= prompt) never parks a row in
            # PREFILLING; nothing to cancel there — config-dependent
            assert state is RequestState.PREFILLING
            continue
        assert reqs[0].state is RequestState.CANCELLED
        assert len(reqs[0].out_tokens) < new_tokens
        assert reqs[0].finish_step >= 0
        # survivors never notice: bit-identical greedy streams
        for i in (1, 2):
            assert reqs[i].state is RequestState.FINISHED
            assert list(reqs[i].out_tokens) == base_streams[i], (
                f"survivor {i} diverged after cancel at {state}")
        assert_no_leaks(eng)
        st = eng.stats()
        assert st["cancelled"] == 1
        assert st["terminal"] == len(prompts)
        assert st["finished"] == len(prompts) - 1


def test_cancel_frees_pages_at_next_round_boundary(model_and_params):
    # a lone decoding request: after cancel + one step, every page is
    # back on the free list — not merely "eventually"
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [12])
    eng = make_engine(model, prompts, 8, layout="paged", params=params)
    req = eng.submit(prompts[0], 8)
    eng.step()
    while req.state is not RequestState.DECODING:
        eng.step()
    assert eng.pool.pages.n_free < eng.pool.pages.num_pages
    assert eng.cancel(req.rid)
    assert req.state is RequestState.DECODING  # not yet: round boundary
    before = eng.pool.pages.lock_stats()["acquires"]
    eng.step()                                 # the next round boundary
    assert req.state is RequestState.CANCELLED
    assert_no_leaks(eng)
    # cancellation frees ride ONE batched critical section (the round's
    # retirement reclaim) — never a per-page or per-request acquire
    assert eng.pool.pages.lock_stats()["acquires"] - before <= 1


def test_cancel_shared_prefix_donor_keeps_adopter_intact(model_and_params):
    # adopter holds refcounts on the donor's prefix pages; cancelling
    # the donor mid-decode must decref, not free, and the adopter's
    # stream must match its solo run
    cfg, model, params = model_and_params
    p = make_prompts(cfg, [16])[0]
    prompts = [p, p]
    new_tokens = 6

    # one-shot prefill: adoption happens at the adopter's admission,
    # so the donor-live overlap below is easy to stage deterministically
    solo_eng = make_engine(model, [p], new_tokens, layout="paged",
                           sharing="on", chunk=None, params=params)
    solo = drive(solo_eng, [p], new_tokens)
    solo_stream = list(solo[0].out_tokens)

    eng = make_engine(model, prompts, new_tokens, layout="paged",
                      sharing="on", chunk=None, params=params)
    done = []

    def on_round(eng_, reqs):
        if done or len(reqs) < 2:
            return
        donor, adopter = reqs[0], reqs[1]
        # cancel the donor once both are in flight and sharing happened
        if (donor.state is RequestState.DECODING
                and not adopter.state.terminal
                and adopter.grant_step >= 0):
            eng_.cancel(donor.rid)
            done.append(True)

    reqs = drive(eng, prompts, new_tokens, arrivals=[0, 2],
                 on_round=on_round)
    assert done, "test setup: donor and adopter never overlapped"
    assert reqs[0].state is RequestState.CANCELLED
    assert reqs[1].state is RequestState.FINISHED
    assert list(reqs[1].out_tokens) == solo_stream
    assert eng.stats()["prefix_hits"] >= 1, "sharing never engaged"
    assert_no_leaks(eng)


def test_cancel_unknown_or_finished_is_refused(model_and_params):
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [8])
    eng = make_engine(model, prompts, 4, params=params)
    assert not eng.cancel(12345)
    reqs = drive(eng, prompts, 4)
    assert reqs[0].state is RequestState.FINISHED
    assert not eng.cancel(reqs[0].rid)
    assert eng.stats()["cancelled"] == 0


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------

def test_queued_past_deadline_expires_before_admission(model_and_params):
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [10, 10, 10])
    eng = make_engine(model, prompts, 8, capacity=1, chunk=None,
                      params=params)
    blocker = eng.submit(prompts[0], 8)
    doomed = eng.submit(prompts[1], 8,
                        deadline_step=eng.step_clock + 1)
    patient = eng.submit(prompts[2], 8)
    rounds = 0
    while eng.queue or eng.active:
        eng.step()
        rounds += 1
        assert rounds < 200
    assert blocker.state is RequestState.FINISHED
    assert doomed.state is RequestState.EXPIRED
    assert doomed.grant_step == -1          # never granted, never paged
    assert patient.state is RequestState.FINISHED
    st = eng.stats()
    assert st["expired"] == 1
    assert st["finished"] == 2
    assert_no_leaks(eng)
    # FIFO grant log never saw the expired rid
    assert doomed.rid not in eng.grant_log


def test_late_eviction_expires_instead_of_requeueing(model_and_params):
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [10])
    eng = make_engine(model, prompts, 8, layout="paged", params=params)
    req = eng.submit(prompts[0], 8, deadline_step=2)
    eng.step()
    while req.state is not RequestState.DECODING:
        eng.step()
    eng.step_clock = 10                     # sail past the deadline
    assert req.past_deadline(eng.step_clock)
    eng._preempt(req.slot)                  # page-pressure eviction path
    assert req.state is RequestState.EXPIRED
    assert req.rid not in [r.rid for r in eng.queue]
    assert eng.stats()["expired"] == 1
    assert_no_leaks(eng)


# ---------------------------------------------------------------------------
# Time-in-state ledger
# ---------------------------------------------------------------------------

def test_time_in_state_partitions_lifetime(model_and_params):
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [14, 6, 9, 12])
    eng = make_engine(model, prompts, 5, params=params)
    reqs = drive(eng, prompts, 5, arrivals=[0, 0, 2, 5])
    for r in reqs:
        assert r.state is RequestState.FINISHED
        assert (r.queued_steps + r.prefill_steps + r.decode_steps
                == r.finish_step - r.arrival_step), r.rid
        assert r.decode_steps > 0
    st = eng.stats()
    for k in ("queue_depth", "active_rows", "terminal", "cancelled",
              "expired", "p50_queued_steps", "p99_queued_steps",
              "p50_prefill_steps", "p99_prefill_steps",
              "p50_decode_steps", "p99_decode_steps",
              "deadline_rows", "late_rows"):
        assert k in st, k
    assert st["queue_depth"] == 0.0
    assert st["active_rows"] == 0.0
    # chunked admission spends rounds PREFILLING on the long prompts
    assert st["p99_prefill_steps"] > 0.0


# ---------------------------------------------------------------------------
# Async front-end
# ---------------------------------------------------------------------------

def test_frontend_streams_across_rounds_and_matches_engine(
        model_and_params):
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [12, 5, 9])
    new_tokens = 6

    base_eng = make_engine(model, prompts, new_tokens, params=params)
    base = drive(base_eng, prompts, new_tokens)
    base_streams = [list(r.out_tokens) for r in base]

    eng = make_engine(model, prompts, new_tokens, params=params)

    async def main():
        async with AsyncFrontend(eng, intake_limit=8) as fe:
            handles = [await fe.submit(p, new_tokens) for p in prompts]
            streams = [await h.collect() for h in handles]
            await fe.drain()
            return fe, handles, streams

    fe, handles, streams = asyncio.run(main())
    assert streams == base_streams          # open loop changes nothing
    assert fe.rounds >= 2                   # tokens arrived over rounds
    for h in handles:
        assert h.state is RequestState.FINISHED
        assert h.ttft_s is not None and h.ttft_s >= 0.0
        assert h.done
    assert_no_leaks(eng)
    st = fe.stats()
    assert st["frontend_shed"] == 0.0
    assert st["frontend_rounds"] == float(fe.rounds)


def test_frontend_mid_stream_cancel_reclaims_and_spares_survivors(
        model_and_params):
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [12, 5])
    eng0 = make_engine(model, prompts, 6, params=params)
    base = drive(eng0, prompts, 6)
    base_stream0 = list(base[0].out_tokens)

    eng = make_engine(model, prompts, 6, params=params)
    state = {}

    async def hook(fe):
        h = state.get("victim")
        if h is not None and h._streamed >= 2 \
                and not h._cancel_requested:
            h.cancel()

    async def main():
        async with AsyncFrontend(eng, intake_limit=8,
                                 round_hook=hook) as fe:
            survivor = await fe.submit(prompts[0], 6)
            victim = await fe.submit(prompts[1], 24)
            state["victim"] = victim
            got_s = await survivor.collect()
            got_v = [t async for t in victim]
            await fe.drain()
            return got_s, got_v, survivor, victim

    got_s, got_v, survivor, victim = asyncio.run(main())
    assert survivor.state is RequestState.FINISHED
    assert got_s == base_stream0
    assert victim.state is RequestState.CANCELLED
    assert 2 <= len(got_v) < 24
    assert victim.out_tokens == got_v       # stream froze at cancel
    assert_no_leaks(eng)
    assert eng.stats()["cancelled"] == 1


def test_frontend_backpressure_sheds_at_intake_bound(model_and_params):
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [8])
    eng = make_engine(model, prompts, 4, capacity=1, params=params)

    async def main():
        fe = AsyncFrontend(eng, intake_limit=2)
        async with fe:
            first = await fe.submit(prompts[0], 4)
            shed = 0
            # burst faster than the loop can transfer: the bound trips
            try:
                for _ in range(50):
                    await fe.submit(prompts[0], 4)
            except IntakeFullError:
                shed = 1
            await fe.drain()
            return fe, first, shed

    fe, first, shed = asyncio.run(main())
    assert shed == 1 and fe.shed >= 1
    assert first.state is RequestState.FINISHED
    assert_no_leaks(eng)
    # the admission gate stayed the sole grant authority
    assert eng.grant_log == sorted(eng.grant_log)


def test_frontend_cancel_in_intake_never_reaches_engine(model_and_params):
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [8, 8])
    eng = make_engine(model, prompts, 4, params=params)

    async def main():
        async with AsyncFrontend(eng, intake_limit=8) as fe:
            keep = await fe.submit(prompts[0], 4)
            drop = await fe.submit(prompts[1], 4)
            drop.cancel()
            toks = await keep.collect()
            dropped = [t async for t in drop]
            await fe.drain()
            return keep, drop, toks, dropped

    keep, drop, toks, dropped = asyncio.run(main())
    assert keep.state is RequestState.FINISHED and len(toks) == 4
    assert drop.state is RequestState.CANCELLED
    assert dropped == []
    assert_no_leaks(eng)


def test_frontend_deadline_expires_queued_request(model_and_params):
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [10, 10])
    eng = make_engine(model, prompts, 8, capacity=1, chunk=None,
                      params=params)

    async def main():
        async with AsyncFrontend(eng, intake_limit=8) as fe:
            blocker = await fe.submit(prompts[0], 8)
            doomed = await fe.submit(prompts[1], 8, deadline_steps=1)
            b = await blocker.collect()
            d = await doomed.collect()
            await fe.drain()
            return blocker, doomed, b, d

    blocker, doomed, b, d = asyncio.run(main())
    assert blocker.state is RequestState.FINISHED and len(b) == 8
    assert doomed.state is RequestState.EXPIRED and d == []
    assert eng.stats()["expired"] == 1
    assert_no_leaks(eng)
