"""Continuous chunked prefill: bit-identity with one-shot prefill.

DESIGN.md §12's correctness bar: chunking is a *schedule* change only —
for greedy decoding, the emitted token streams must be bit-identical to
one-shot whole-prompt prefill for every chunk size, both KV layouts, and
with prefix sharing on or off. The engine-level tests drive the real
``SlotServeEngine`` round loop (admission, planner, page growth,
completion sampling); the model-level test isolates the chunked
attention math itself.
"""

import numpy as np
import pytest

import jax

from repro.configs import get_arch
from repro.models import build_model
from repro.serve.engine import SlotServeEngine


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_arch("qwen3-14b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_prompts(cfg, lens, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def run_engine(model, params, prompts, *, chunk=None, layout="slots",
               sharing="off", arrivals=None, new_tokens=5, capacity=2,
               **kw):
    """Serve every prompt to completion; returns (engine, streams)."""
    max_len = max(len(p) for p in prompts) + new_tokens + 1
    eng = SlotServeEngine(model, params, capacity=capacity,
                          max_len=max_len, decode_chunk=2, seed=0,
                          kv_layout=layout, page_size=8,
                          prefix_sharing=sharing,
                          prefill_chunk_tokens=chunk, **kw)
    arr = (np.zeros(len(prompts)) if arrivals is None
           else np.asarray(arrivals))
    reqs, nxt = [], 0
    while nxt < len(prompts) or eng.queue or eng.active:
        while nxt < len(prompts) and arr[nxt] <= eng.step_clock:
            reqs.append(eng.submit(prompts[nxt], new_tokens))
            nxt += 1
        if eng.step() == 0 and not eng.queue and nxt < len(prompts):
            eng.step_clock += 1  # idle tick toward the next arrival
    return eng, [list(r.out_tokens) for r in reqs]


def test_whole_prompt_chunk_matches_one_shot_prefill(model_and_params):
    # the model-level identity the engine relies on: prefilling the
    # entire prompt as ONE chunk against a zero decode cache produces
    # the same next-token distribution as the one-shot prefill path
    cfg, model, params = model_and_params
    lp, max_len = 12, 24
    prompt = make_prompts(cfg, [lp])[0]
    logits_os, _ = model.prefill(params, {"tokens": prompt[None, :]},
                                 max_len=max_len)
    cache = model.init_cache(1, max_len)
    pos = np.arange(lp, dtype=np.int32)[None, :]
    logits_ch, _ = model.prefill_chunk(
        params, cache, prompt[None, :], pos, pos)
    last = np.asarray(logits_ch[:, -1, :])
    ref = np.asarray(logits_os)
    assert int(np.argmax(last)) == int(np.argmax(ref))
    np.testing.assert_allclose(last, ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("layout", ["slots", "paged"])
@pytest.mark.parametrize("chunk", [1, 6, 64])
def test_chunked_streams_match_one_shot(model_and_params, layout, chunk):
    # chunk sizes straddle the interesting regimes: 1 (every position
    # its own round), mid-prompt (partial chunks + pad lanes), and
    # >= prompt (degenerate single-chunk prefill)
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [12, 5, 9, 12])
    _, base = run_engine(model, params, prompts, layout=layout)
    eng, got = run_engine(model, params, prompts, chunk=chunk,
                          layout=layout)
    assert eng.prefill_chunk == chunk  # gate did not silently disable
    assert got == base


@pytest.mark.parametrize("sharing", ["on", "off"])
def test_chunked_streams_match_with_prefix_sharing(model_and_params,
                                                   sharing):
    # repeated prompts on the paged arena: with sharing on, chunked
    # admission adopts a live donor's prefix pages (skipping whole
    # chunks) and must still emit the identical stream
    cfg, model, params = model_and_params
    p = make_prompts(cfg, [16])[0]
    prompts, arrivals = [p, p], [0, 6]
    _, base = run_engine(model, params, prompts, layout="paged",
                         sharing=sharing, arrivals=arrivals,
                         new_tokens=8)
    eng, got = run_engine(model, params, prompts, chunk=8,
                          layout="paged", sharing=sharing,
                          arrivals=arrivals, new_tokens=8)
    assert got == base
    assert got[0] == got[1]  # identical prompts, greedy: same stream
    if sharing == "on":
        st = eng.stats()
        assert st["prefix_hits"] >= 1
        assert st["shared_pages_adopted"] >= 1


def test_chunked_counters_account_for_every_prompt_token(
        model_and_params):
    cfg, model, params = model_and_params
    lens, chunk = [12, 5, 9, 12], 6
    prompts = make_prompts(cfg, lens)
    eng, _ = run_engine(model, params, prompts, chunk=chunk)
    st = eng.stats()
    assert st["prefill_tokens"] == sum(lens)
    assert st["prefill_chunks"] == sum(-(-n // chunk) for n in lens)
    # pad lanes: each prompt's last chunk pads to the fixed chunk width
    assert st["pad_tokens"] == sum(-(-n // chunk) * chunk - n
                                   for n in lens)
    padf = st["pad_tokens"] / (st["pad_tokens"] + st["prefill_tokens"])
    assert st["pad_fraction"] == pytest.approx(padf)
    # structural property of the interleaved schedule: a decode round
    # never waits on a whole-prompt prefill dispatch
    assert st["decode_rounds_stalled_by_prefill"] == 0


def test_chunked_prefill_gated_off_for_sampling(model_and_params):
    # temperature > 0 cannot keep streams comparable across schedules
    # (the completion token's key order differs), so the engine must
    # fall back to one-shot prefill rather than change outputs
    cfg, model, params = model_and_params
    prompts = make_prompts(cfg, [8, 8])
    eng, got = run_engine(model, params, prompts, chunk=4,
                          temperature=0.7)
    assert eng.prefill_chunk == 0
    assert all(len(s) == 5 for s in got)
