"""Slot-pool serving: arena correctness, engine round-trips, and
host-semaphore vs Algorithm-5-kernel admission equivalence."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is optional in this image (tests/_hypothesis_compat.py)
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.configs import get_arch
from repro.core.hostsync import SleepingSemaphore
from repro.kernels.semaphore.ops import (semaphore_admission,
                                         semaphore_admission_window)
from repro.models import build_model
from repro.serve.engine import ServeEngine, SlotServeEngine
from repro.serve.kv_slots import SlotPool


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_arch("qwen3-14b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ----------------------------------------------------------------- slot pool
def test_slot_pool_insert_evict_roundtrip(lm_setup):
    cfg, model, params = lm_setup
    pool = SlotPool(model, capacity=3, max_len=24)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                 cfg.vocab_size)
    _, c0 = model.prefill(params, {"tokens": prompts[0:1]}, max_len=24)
    _, c1 = model.prefill(params, {"tokens": prompts[1:2]}, max_len=24)

    s0 = pool.acquire(rid=10)
    s1 = pool.acquire(rid=11)
    assert (s0, s1) == (0, 1)       # FIFO slot reuse order
    pool.insert(s0, c0, 6)
    pool.insert(s1, c1, 6)
    assert pool.n_free == 1 and pool.n_active == 2
    np.testing.assert_array_equal(np.asarray(pool.lens), [6, 6, 0])

    # arena row s0 holds c0's KV: compare one periods leaf
    arena_k = np.asarray(
        pool.arena["periods"]["layer_0"]["k"])       # [NP, K, S, KV, hd]
    want_k = np.asarray(c0["periods"]["layer_0"]["k"])  # [NP, 1, S, KV, hd]
    np.testing.assert_allclose(arena_k[:, s0:s0 + 1, :6], want_k[:, :, :6],
                               rtol=1e-5, atol=1e-5)

    pool.evict(s0)
    assert pool.n_free == 2
    s2 = pool.acquire(rid=12)
    assert s2 == 2                  # FIFO: slot 2 reused before slot 0
    with pytest.raises(RuntimeError):
        pool.evict(s0)              # double-evict is an error


def test_slot_pool_encdec_batch_axes():
    cfg = get_arch("whisper-small").reduced()
    model = build_model(cfg)
    pool = SlotPool(model, capacity=2, max_len=8)
    # every leaf carries the capacity on its detected batch axis
    for leaf in jax.tree_util.tree_leaves(pool.arena):
        assert 2 in leaf.shape


# -------------------------------------------------------------- slot engine
def test_slot_engine_n_gt_k_roundtrip(lm_setup):
    cfg, model, params = lm_setup
    eng = SlotServeEngine(model, params, capacity=3, max_len=48,
                          decode_chunk=2)
    rng = np.random.default_rng(0)
    for _ in range(7):
        eng.submit(rng.integers(0, cfg.vocab_size, 10), max_new_tokens=5)
    eng.run_until_done(max_rounds=100)
    assert len(eng.finished) == 7
    assert eng.grant_log == sorted(eng.grant_log)          # FIFO grants
    assert all(len(r.out_tokens) == 5 for r in eng.finished)
    assert eng.admission.in_flight == 0                    # sem drained
    st_ = eng.stats()
    assert st_["p99_wait_steps"] >= st_["p50_wait_steps"] >= 0


def test_slot_engine_matches_legacy_greedy(lm_setup):
    """Batched slot decode must be token-identical to the legacy
    per-request loop under greedy sampling (same params, same prompts)."""
    cfg, model, params = lm_setup
    eng = SlotServeEngine(model, params, capacity=2, max_len=32)
    legacy = ServeEngine(model, params, max_len=32)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 7) for _ in range(3)]
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    eng.run_until_done(max_rounds=50)
    for req in sorted(eng.finished, key=lambda r: r.rid):
        out = legacy.generate(
            {"tokens": jnp.asarray(req.prompt)[None, :]}, 4)
        assert req.out_tokens == np.asarray(out.tokens)[0].tolist()


def test_slot_engine_eos_frees_slot_early(lm_setup):
    cfg, model, params = lm_setup
    eng = SlotServeEngine(model, params, capacity=1, max_len=32,
                          eos_id=0, decode_chunk=1)
    rng = np.random.default_rng(2)
    for _ in range(2):
        eng.submit(rng.integers(1, cfg.vocab_size, 6), max_new_tokens=12)
    eng.run_until_done(max_rounds=60)
    assert len(eng.finished) == 2
    for r in eng.finished:
        if r.eos:
            assert r.out_tokens[-1] == 0
            assert len(r.out_tokens) <= 12
        else:
            assert len(r.out_tokens) == 12


def test_slot_engine_rejects_oversized_prompt(lm_setup):
    cfg, model, params = lm_setup
    eng = SlotServeEngine(model, params, capacity=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(14, np.int32), max_new_tokens=4)


# ------------------------------------------------- model-level vector lens
def test_decode_step_vector_lens_match_scalar(lm_setup):
    """One batched decode over rows at different depths == two scalar-len
    decodes run separately (the refactor that lets slots share a step)."""
    cfg, model, params = lm_setup
    max_len = 16
    pa = jax.random.randint(jax.random.PRNGKey(3), (1, 5), 0, cfg.vocab_size)
    pb = jax.random.randint(jax.random.PRNGKey(4), (1, 9), 0, cfg.vocab_size)
    la, ca = model.prefill(params, {"tokens": pa}, max_len=max_len)
    lb, cb = model.prefill(params, {"tokens": pb}, max_len=max_len)

    pool = SlotPool(model, capacity=2, max_len=max_len)
    pool.insert(pool.acquire(0), ca, 5)
    pool.insert(pool.acquire(1), cb, 9)
    tok = jnp.asarray([int(jnp.argmax(la[0])), int(jnp.argmax(lb[0]))],
                      jnp.int32)
    logits_vec, cache_vec = model.decode_step(params, pool.cache_view(), tok)

    la2, _ = model.decode_step(params, ca, tok[0:1])
    lb2, _ = model.decode_step(params, cb, tok[1:2])
    np.testing.assert_allclose(np.asarray(logits_vec[0]), np.asarray(la2[0]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(logits_vec[1]), np.asarray(lb2[0]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(np.asarray(cache_vec["len"]), [6, 10])


def test_prefill_padded_length_matches_exact(lm_setup):
    """Right-padded prefill with a length vector == exact-length prefill."""
    cfg, model, params = lm_setup
    p = jax.random.randint(jax.random.PRNGKey(5), (1, 6), 0, cfg.vocab_size)
    exact_logits, _ = model.prefill(params, {"tokens": p}, max_len=16)
    padded = jnp.pad(p, ((0, 0), (0, 6)))
    pad_logits, cache = model.prefill(
        params, {"tokens": padded}, max_len=16,
        length=jnp.asarray([6], jnp.int32))
    np.testing.assert_allclose(np.asarray(pad_logits), np.asarray(exact_logits),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(np.asarray(cache["len"]), [6])


# ------------------------------------- host semaphore vs kernel timeline
def _host_semaphore_trace(n, capacity, completion_rng):
    """Run n FIFO arrivals through the real SleepingSemaphore with the
    main thread driving completions one at a time (in a random granted
    order), so each post() produces exactly one deterministic handoff.

    Arrival order is enforced by watching the semaphore's own count word
    (no posts happen during the spawn window — completions are gated on
    events the main thread sets afterwards). Returns (grant_order,
    max_occupancy)."""
    sem = SleepingSemaphore(capacity)
    lock = threading.Lock()
    order = []
    gauge = {"now": 0, "max": 0}
    release = [threading.Event() for _ in range(n)]

    def worker(i):
        sem.wait()
        with lock:
            order.append(i)
            gauge["now"] += 1
            gauge["max"] = max(gauge["max"], gauge["now"])
        release[i].wait(timeout=10.0)
        with lock:
            gauge["now"] -= 1
        sem.post()

    def grants():
        with lock:
            return len(order)

    def wait_until(pred):
        deadline = time.monotonic() + 5.0
        while not pred():
            assert time.monotonic() < deadline, "host trace timed out"
            time.sleep(1e-4)

    threads = []
    for i in range(n):
        t = threading.Thread(target=worker, args=(i,))
        t.start()
        threads.append(t)
        # count increments exactly once per wait() entry; no posts yet
        wait_until(lambda: sem._count.load() >= i + 1)
    wait_until(lambda: grants() >= min(capacity, n))

    done = set()
    while len(done) < n:
        with lock:
            candidates = [i for i in order if i not in done]
        nxt = candidates[completion_rng.integers(len(candidates))]
        expect = min(n, grants() + 1)           # one handoff per post
        release[nxt].set()
        done.add(nxt)
        wait_until(lambda: grants() >= expect)
    for t in threads:
        t.join()
    return order, gauge["max"]


@settings(max_examples=5, deadline=None)
@given(n=st.integers(6, 16), cap=st.integers(1, 4),
       seed=st.integers(0, 10_000))
def test_admission_equivalence_host_vs_kernel(n, cap, seed):
    """Property: the real Algorithm-5 host semaphore and the Pallas
    admission kernel agree on a FIFO arrival trace — same waited set,
    FIFO handoff order among waiters, occupancy <= K — even when holds
    complete out of order."""
    rng = np.random.default_rng(seed)
    holds = rng.integers(1, 4, n).astype(np.float32)
    # kernel timeline: arrivals strictly increasing, gaps tiny vs holds
    arrivals = np.arange(n, dtype=np.float32) * 1e-3
    g, r, waited = semaphore_admission_window(
        arrivals, holds, capacity=cap, window=32)
    # under-capacity prefix enters immediately; the rest queue
    assert list(waited) == [0] * min(cap, n) + [1] * max(n - cap, 0)
    assert np.all(np.diff(g) >= -1e-5)          # FIFO: grants monotone
    for i in range(n):                          # occupancy bound
        assert np.sum((g <= g[i] + 1e-6) & (r > g[i] + 1e-6)) <= cap

    order, max_occ = _host_semaphore_trace(n, cap, rng)
    assert max_occ <= cap
    # the non-waited set is the first `cap` arrivals (granted in any
    # interleaving); every ticketed waiter is handed off FIFO — exactly
    # the kernel's deterministic grant order
    k = min(cap, n)
    assert sorted(order[:k]) == list(range(k))
    assert order[k:] == list(range(k, n))


def test_admission_window_matches_unpadded():
    arr = np.asarray([0.0, 0.5, 0.6, 2.0], np.float32)
    hold = np.asarray([1.0, 3.0, 0.5, 1.0], np.float32)
    gw, rw, ww = semaphore_admission_window(arr, hold, capacity=2, window=16)
    g, r, w = semaphore_admission(jnp.asarray(arr), jnp.asarray(hold),
                                  capacity=2)
    np.testing.assert_allclose(gw, np.asarray(g), rtol=1e-6)
    np.testing.assert_allclose(rw, np.asarray(r), rtol=1e-6)
    np.testing.assert_array_equal(ww, np.asarray(w))


def test_admission_window_overflow_buckets_up():
    """A burst longer than the window buckets to the next power-of-2
    window (it used to raise ValueError on the serve hot loop) and still
    matches the unpadded timeline."""
    arr = np.sort(np.random.default_rng(3).uniform(0, 4, 21)
                  ).astype(np.float32)
    hold = np.random.default_rng(4).uniform(1, 2, 21).astype(np.float32)
    gw, rw, ww = semaphore_admission_window(arr, hold, capacity=3,
                                            window=16)
    assert gw.shape == (21,)
    g, r, w = semaphore_admission(jnp.asarray(arr), jnp.asarray(hold),
                                  capacity=3)
    np.testing.assert_allclose(gw, np.asarray(g), rtol=1e-6)
    np.testing.assert_allclose(rw, np.asarray(r), rtol=1e-6)
    np.testing.assert_array_equal(ww, np.asarray(w))
