"""Machine abstraction: parameters, classification, Table-5 selection."""

import math

from repro.core.abstraction import (FERMI, TESLA, TPU_V5E, PrimitiveKind,
                                    classify, select_impl)


def test_p1_ratios_match_paper():
    # paper Table 3: contentious atomics 92x (Tesla) / ~3x (Fermi)
    assert 85 < TESLA.atomic_volatile_ratio < 100
    assert 2 < FERMI.atomic_volatile_ratio < 4


def test_p2_ratios_match_paper():
    # paper Table 2: volatile contention 1.44x (Tesla) / 11.5x (Fermi)
    assert 1.2 < TESLA.contention_ratio < 1.7
    assert 10 < FERMI.contention_ratio < 13


def test_p3_line_hostage():
    assert not TESLA.line_hostage
    assert FERMI.line_hostage


def test_classification():
    assert classify(TESLA) == "tesla-class"
    assert classify(FERMI) == "fermi-class"
    assert classify(TPU_V5E) == "no-atomics"
    assert not TPU_V5E.has_atomics
    assert math.isinf(TPU_V5E.atomic_volatile_ratio)


def test_table5_selection_reproduced():
    """select_impl must reproduce the paper's Table 5 from the ratios."""
    assert select_impl(TESLA, PrimitiveKind.BARRIER).algorithm == "xf"
    assert select_impl(FERMI, PrimitiveKind.BARRIER).algorithm == "xf"
    assert select_impl(TESLA, PrimitiveKind.MUTEX).algorithm == "fa"
    assert select_impl(FERMI, PrimitiveKind.MUTEX).algorithm == "spin_backoff"
    assert select_impl(TESLA, PrimitiveKind.SEMAPHORE,
                       semaphore_initial=1).algorithm == "sleeping"
    assert select_impl(FERMI, PrimitiveKind.SEMAPHORE,
                       semaphore_initial=1).algorithm == "spin_backoff"
    assert select_impl(TESLA, PrimitiveKind.SEMAPHORE,
                       semaphore_initial=120).algorithm == "sleeping"
    assert select_impl(FERMI, PrimitiveKind.SEMAPHORE,
                       semaphore_initial=120).algorithm == "sleeping"


def test_no_atomics_machine_gets_flag_algorithms():
    assert select_impl(TPU_V5E, PrimitiveKind.MUTEX).algorithm == "fa"
    assert select_impl(TPU_V5E, PrimitiveKind.BARRIER).algorithm == "xf"
    assert select_impl(TPU_V5E, PrimitiveKind.SEMAPHORE).algorithm == "sleeping"


def test_service_time_derivations():
    # contentious throughput: 240k accesses in 78.407 ms
    svc = TESLA.atomic_service_us(write=False)
    assert abs(svc - 78.407e3 / 240_000) < 1e-6
    # noncontentious latency: 0.59 ms per 1000 reads
    assert abs(TESLA.volatile_latency_us(False) - 0.59) < 1e-9
