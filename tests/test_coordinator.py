"""Control plane: barriers w/ stragglers, heartbeats, membership, fences."""

import threading

from repro.core.coordinator import (ClusterCoordinator, InMemoryKV,
                                    KVCoordinator)


def _run(n, fn):
    ts = [threading.Thread(target=fn, args=(r,)) for r in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()


def test_step_barrier_and_fence():
    c = ClusterCoordinator(4, barrier_timeout_s=10)
    ok = [True] * 4

    def host(r):
        for step in range(15):
            c.heartbeat(r, step)
            if not c.step_barrier(r).ok:
                ok[r] = False
        if not c.checkpoint_fence(r):
            ok[r] = False

    _run(4, host)
    assert all(ok)


def test_straggler_attribution_on_timeout():
    c = ClusterCoordinator(3, barrier_timeout_s=0.3)
    outcomes = {}

    def host(r):
        outcomes[r] = c.step_barrier(r)

    # rank 2 never arrives
    _run(2, host)
    assert not outcomes[0].ok
    assert outcomes[0].stragglers == [2]


def test_heartbeat_stragglers():
    c = ClusterCoordinator(4, heartbeat_lag_steps=2)
    for r in range(4):
        c.heartbeat(r, 10)
    c.heartbeat(3, 3)  # rank 3 fell behind
    assert c.stragglers() == [3]


def test_membership_evict_join():
    c = ClusterCoordinator(4)
    v0 = c.view()
    assert v0.world_size == 4
    v1 = c.evict(2)
    assert v1.alive == [0, 1, 3]
    assert v1.epoch == v0.epoch + 1
    v2 = c.join(2)
    assert v2.alive == [0, 1, 2, 3]
    assert v2.epoch == v1.epoch + 1


def test_kv_coordinator_barrier():
    kv = InMemoryKV()
    coords = [KVCoordinator(kv, 3, r) for r in range(3)]
    outs = [None] * 3

    def host(r):
        outs[r] = coords[r].barrier(timeout_s=10)

    _run(3, host)
    assert all(o.ok for o in outs)


def test_kv_coordinator_straggler():
    kv = InMemoryKV()
    coords = [KVCoordinator(kv, 3, r, barrier_timeout_s=0.3)
              for r in range(3)]
    outs = {}

    def host(r):
        outs[r] = coords[r].barrier()

    _run(2, host)  # rank 2 absent
    assert not outs[0].ok
    assert outs[0].stragglers == [2]
    hb = coords[0]
    hb.heartbeat(5)
    assert coords[1].read_heartbeats()[0] == 5
