"""Paper Figures 1-3 + Table 5: primitive ops/sec vs concurrency.

Simulated Tesla (GTX295) and Fermi (GTX580) sweeps of every implementation
the paper compares:

  Figure 1 (barrier):   two-stage atomic counter vs XF flag barrier
  Figure 2 (mutex):     spin, spin+backoff, FA(+backoff)
  Figure 3 (semaphore): spin, spin+backoff, sleeping x initial value

plus the 'Host' row measured with real threads (hostbench), the Table-5
best-implementation auto-selection check, and the per-primitive
per-backend plan latency of the unified ``repro.sync`` surface (host
threading vs Pallas-interpret kernel vs pure-jnp ref).

``--smoke`` runs the backend-latency + selection sections only and
writes ``BENCH_primitives.json`` so CI records the primitives' perf
trajectory alongside ``BENCH_serve.json``.
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.abstraction import (FERMI, TESLA, PrimitiveKind, classify,
                                    select_impl)
from repro.core.primitives_sim import run_primitive

# Block counts swept (paper: 1..240 Tesla / 1..128 Fermi; we subsample).
TESLA_BLOCKS = (1, 8, 30, 60, 120, 240)
FERMI_BLOCKS = (1, 8, 16, 32, 64, 128)

# The paper truncates the Tesla spin-lock curves past ~120-130 blocks
# ("unpredictable and poor"); the simulator reproduces that regime, so we
# apply the same cap + a smaller event budget there.
SPIN_CAP_TESLA = 120


def _fmt(rows, name, us, detail):
    rows.append(f"{name},{us:.1f},{detail}")


def sweep(machine, name, blocks_list, *, ops=20) -> List[str]:
    rows: List[str] = []

    # ---- Figure 1: barriers
    for impl in ("atomic", "xf"):
        for nb in blocks_list:
            t0 = time.perf_counter()
            r = run_primitive(machine, "barrier", impl, blocks=nb, ops=ops)
            us = (time.perf_counter() - t0) * 1e6
            _fmt(rows, f"fig1_{name}_barrier_{impl}_b{nb}", us,
                 f"ops_per_s={r.ops_per_sec:.0f}"
                 f"{';TRUNC' if r.truncated else ''}")

    # ---- Figure 2: mutexes
    for impl in ("spin", "spin_backoff", "fa"):
        for nb in blocks_list:
            if name == "tesla" and impl == "spin" and nb > SPIN_CAP_TESLA:
                continue
            t0 = time.perf_counter()
            r = run_primitive(machine, "mutex", impl, blocks=nb, ops=ops,
                              max_events=8_000_000)
            us = (time.perf_counter() - t0) * 1e6
            _fmt(rows, f"fig2_{name}_mutex_{impl}_b{nb}", us,
                 f"ops_per_s={r.ops_per_sec:.0f};fair={int(r.fair_fifo)};"
                 f"viol={r.violations}{';TRUNC' if r.truncated else ''}")

    # ---- Figure 3: semaphores x initial value
    for init in (1, 2, 10, 120):
        for impl in ("spin", "spin_backoff", "sleeping"):
            for nb in blocks_list:
                if name == "tesla" and impl.startswith("spin") \
                        and nb > SPIN_CAP_TESLA:
                    continue
                t0 = time.perf_counter()
                r = run_primitive(machine, "semaphore", impl, blocks=nb,
                                  ops=min(ops, 10), initial=init,
                                  max_events=5_000_000)
                us = (time.perf_counter() - t0) * 1e6
                _fmt(rows, f"fig3_{name}_sem{init}_{impl}_b{nb}", us,
                     f"ops_per_s={r.ops_per_sec:.0f};viol={r.violations}"
                     f"{';TRUNC' if r.truncated else ''}")
    return rows


def table5_check() -> List[str]:
    """Auto-selection (select_impl) vs the paper's Table 5."""
    rows: List[str] = []
    expected = {
        ("tesla", "barrier"): "xf",
        ("fermi", "barrier"): "xf",
        ("tesla", "mutex"): "fa",
        ("fermi", "mutex"): "spin_backoff",
        ("tesla", "sem_low"): "sleeping",
        ("fermi", "sem_low"): "spin_backoff",
        ("tesla", "sem_high"): "sleeping",
        ("fermi", "sem_high"): "sleeping",
    }
    t0 = time.perf_counter()
    got = {
        ("tesla", "barrier"): select_impl(TESLA, PrimitiveKind.BARRIER).algorithm,
        ("fermi", "barrier"): select_impl(FERMI, PrimitiveKind.BARRIER).algorithm,
        ("tesla", "mutex"): select_impl(TESLA, PrimitiveKind.MUTEX).algorithm,
        ("fermi", "mutex"): select_impl(FERMI, PrimitiveKind.MUTEX).algorithm,
        ("tesla", "sem_low"): select_impl(
            TESLA, PrimitiveKind.SEMAPHORE, semaphore_initial=1).algorithm,
        ("fermi", "sem_low"): select_impl(
            FERMI, PrimitiveKind.SEMAPHORE, semaphore_initial=1).algorithm,
        ("tesla", "sem_high"): select_impl(
            TESLA, PrimitiveKind.SEMAPHORE, semaphore_initial=120).algorithm,
        ("fermi", "sem_high"): select_impl(
            FERMI, PrimitiveKind.SEMAPHORE, semaphore_initial=120).algorithm,
    }
    us = (time.perf_counter() - t0) * 1e6
    n_match = sum(got[k] == expected[k] for k in expected)
    detail = ";".join(f"{k[0]}.{k[1]}={got[k]}" +
                      ("" if got[k] == expected[k] else f"(paper:{expected[k]})")
                      for k in expected)
    rows.append(f"table5_selection,{us:.1f},match={n_match}/8;{detail}")
    rows.append(f"table5_classes,{0.0:.1f},"
                f"tesla={classify(TESLA)};fermi={classify(FERMI)}")
    return rows


def headline_speedups(ops: int = 20) -> List[str]:
    """Paper Section 7 headline numbers."""
    rows: List[str] = []
    t0 = time.perf_counter()
    tes_spin = run_primitive(TESLA, "mutex", "spin", blocks=120, ops=ops,
                             max_events=8_000_000)
    tes_fa = run_primitive(TESLA, "mutex", "fa", blocks=240, ops=ops)
    fer_spin = run_primitive(FERMI, "mutex", "spin", blocks=128, ops=ops)
    fer_bo = run_primitive(FERMI, "mutex", "spin_backoff", blocks=128, ops=ops)
    fer_sem_spin = run_primitive(FERMI, "semaphore", "spin", blocks=128,
                                 ops=10, initial=120, max_events=5_000_000)
    fer_sem_slp = run_primitive(FERMI, "semaphore", "sleeping", blocks=128,
                                ops=10, initial=120)
    tes_sem_spin = run_primitive(TESLA, "semaphore", "spin_backoff",
                                 blocks=120, ops=10, initial=10,
                                 max_events=5_000_000)
    tes_sem_slp = run_primitive(TESLA, "semaphore", "sleeping", blocks=120,
                                ops=10, initial=10)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        f"headline_fa_vs_spin_tesla,{us:.1f},"
        f"x={tes_fa.ops_per_sec / tes_spin.ops_per_sec:.1f};paper=40")
    rows.append(
        f"headline_backoff_gain_fermi,{0.0:.1f},"
        f"pct={100 * (fer_bo.ops_per_sec / fer_spin.ops_per_sec - 1):.0f};paper=40")
    rows.append(
        f"headline_sleepsem_vs_spin_fermi,{0.0:.1f},"
        f"x={fer_sem_slp.ops_per_sec / fer_sem_spin.ops_per_sec:.1f};paper=70")
    rows.append(
        f"headline_sleepsem_vs_spin_tesla,{0.0:.1f},"
        f"x={tes_sem_slp.ops_per_sec / tes_sem_spin.ops_per_sec:.1f};paper=3")
    return rows


def backend_latency_rows(
    *, n: int = 12, capacity: int = 3, repeats: int = 3,
    backends: Tuple[str, ...] = ("host", "kernel", "ref"),
) -> Tuple[List[str], Dict[str, Dict[str, float]]]:
    """Per-primitive per-backend plan latency of the unified sync API.

    The kernel/ref numbers are the post-compile hot-path cost the serving
    scheduler pays per replanning round; the host number is the cost of
    an *observed execution* with real threads (the equivalence oracle,
    never on a hot loop)."""
    from repro.sync import SyncLibrary
    lib = SyncLibrary.host_default()
    rng = np.random.default_rng(0)
    arrivals = np.sort(rng.uniform(0, 3, n)).astype(np.float32)
    holds = rng.uniform(1, 3, n).astype(np.float32)
    arrival_perm = rng.permutation(n).astype(np.int32)
    present = np.ones(n, np.int64)

    plans = {
        "semaphore": lambda be: lib.plan_semaphore(
            arrivals, holds, capacity, backend=be),
        "mutex": lambda be: lib.plan_mutex(arrival_perm, backend=be),
        "barrier": lambda be: lib.plan_barrier(
            present, epoch=1, backend=be),
    }
    rows: List[str] = []
    data: Dict[str, Dict[str, float]] = {}
    for prim, plan in plans.items():
        data[prim] = {}
        for be in backends:
            plan(be)  # warm (compile for the jitted backends)
            times = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                plan(be)
                times.append((time.perf_counter() - t0) * 1e6)
            us = float(np.median(times))
            data[prim][be] = us
            rows.append(f"sync_{prim}_{be},{us:.1f},n={n};plan_latency")
    return rows, data


def alloc_sweep(
    out: str, *, num_pages: int = 192, page_size: int = 8,
    strategies: Tuple[str, ...] = ("spin", "spin_backoff", "sleeping",
                                   "adaptive"),
    threads_list: Tuple[int, ...] = (1, 2, 4, 8),
    ops_per_thread: int = 400,
) -> List[str]:
    """Spin vs spin_backoff vs sleeping vs adaptive on the REAL
    ``PagePool`` hot loop (not a simulator): every thread churns batched
    alloc/free requests against one pool, so the guarding ticket lock
    sees exactly the serving allocator's access pattern. Thread count is
    the contention level; the adaptive arm re-tunes its wait strategy
    from the measured contended-acquire window between its own
    operations (the between-rounds contract). Writes ``out``
    (BENCH_alloc.json): per-strategy per-thread-count ops/s, contended
    fraction, held time, and the strategy the adaptive arm settled on.
    """
    from repro.serve.kv_pages import PagePool, PagePoolExhausted
    from repro.sync import SyncLibrary

    lib = SyncLibrary.host_default()
    rows: List[str] = []
    data: Dict[str, Dict[str, dict]] = {}
    for strat in strategies:
        data[strat] = {}
        for nt in threads_list:
            if strat == "spin" and nt > 2:
                # raw spin under real contention starves the lock holder
                # (same regime the paper truncates the Tesla spin curves
                # in: "unpredictable and poor") — record the truncation
                # instead of burning minutes measuring it
                rows.append(f"alloc_{strat}_t{nt},0.0,TRUNC")
                data[strat][str(nt)] = {"truncated": True}
                continue
            pool = PagePool(num_pages, page_size, sync=lib,
                            wait_mode=strat)
            start = threading.Barrier(nt + 1)

            def worker(tid, pool=pool, start=start, nt=nt, strat=strat):
                rng = np.random.default_rng(tid)
                held: List[np.ndarray] = []
                start.wait()
                for i in range(ops_per_thread):
                    if strat == "adaptive" and tid == 0 and i % 32 == 31:
                        pool.retune()      # between ops, never while held
                    n = int(rng.integers(1, 4))
                    # keep the pool near-full so waiting really happens
                    if held and (len(held) > 6
                                 or pool.n_free < 4 * nt):
                        pool.free_batch([held.pop(rng.integers(len(held)))])
                    try:
                        ids = pool.alloc_batch([n], [tid])[0]
                        held.append(ids)
                    except PagePoolExhausted:
                        pass               # exhausted: free next iteration
                if held:
                    pool.free_batch(held)

            threads = [threading.Thread(target=worker, args=(t,))
                       for t in range(nt)]
            for t in threads:
                t.start()
            start.wait()
            t0 = time.perf_counter()
            for t in threads:
                t.join()
            dt = time.perf_counter() - t0
            pool.check()
            st = pool.lock_stats()
            got = {
                "wall_s": dt,
                "lock_acquires": int(st["acquires"]),
                "acquires_per_s": st["acquires"] / dt if dt else 0.0,
                "contended_fraction": (st["contended_acquires"]
                                       / max(st["acquires"], 1)),
                "held_s": st["held_s"],
                "strategy_final": st["strategy"],
                "retunes": int(st.get("retunes", 0)),
            }
            data[strat][str(nt)] = got
            rows.append(
                f"alloc_{strat}_t{nt},{dt * 1e6:.1f},"
                f"acq_per_s={got['acquires_per_s']:.0f};"
                f"contended={got['contended_fraction']:.2f};"
                f"final={got['strategy_final']}")
    blob = {"num_pages": num_pages, "page_size": page_size,
            "ops_per_thread": ops_per_thread, "arms": data}
    with open(out, "w") as f:
        json.dump(blob, f, indent=2)
    rows.append(f"# wrote {out}")
    return rows


def main(fast: bool = True) -> List[str]:
    blocks_t = TESLA_BLOCKS if not fast else (1, 30, 120, 240)
    blocks_f = FERMI_BLOCKS if not fast else (1, 32, 128)
    rows = sweep(TESLA, "tesla", blocks_t)
    rows += sweep(FERMI, "fermi", blocks_f)
    rows += table5_check()
    rows += headline_speedups()
    rows += backend_latency_rows()[0]
    return rows


def smoke(out: str) -> List[str]:
    """CI tier: backend latencies + selection check -> JSON artifact."""
    rows, backends = backend_latency_rows()
    t5 = table5_check()
    rows += t5
    blob = {
        "backends_plan_latency_us": backends,
        "table5": t5[0].split(",", 2)[2],
        "machine_classes": t5[1].split(",", 2)[2],
    }
    with open(out, "w") as f:
        json.dump(blob, f, indent=2)
    rows.append(f"# wrote {out}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="backend-latency + selection sections only; "
                         "write the JSON artifact")
    ap.add_argument("--alloc-sweep", action="store_true",
                    help="wait-strategy sweep on the real PagePool hot "
                         "loop; writes the BENCH_alloc.json artifact")
    ap.add_argument("--out", default="BENCH_primitives.json")
    ap.add_argument("--alloc-out", default="BENCH_alloc.json")
    args = ap.parse_args()
    if args.alloc_sweep:
        for r in alloc_sweep(args.alloc_out):
            print(r)
    elif args.smoke:
        for r in smoke(args.out):
            print(r)
    else:
        for r in main(fast=False):
            print(r)
