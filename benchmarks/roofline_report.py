"""Roofline report: read dry-run artifacts -> per-cell three-term table.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits
the §Roofline table: compute/memory/collective terms (seconds), dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and the perfect-overlap MFU bound.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ART_DIR = os.environ.get("DRYRUN_ART", "artifacts/dryrun")


def load_records(art_dir: str = ART_DIR) -> List[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def render_table(recs: List[dict], mesh: Optional[str] = "16x16") -> List[str]:
    rows = []
    header = ("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
              "bottleneck,useful_flops_ratio,mfu_bound")
    rows.append(header)
    for r in recs:
        if mesh and r["mesh"] != mesh:
            continue
        rows.append(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{r['t_compute']:.4f},{r['t_memory']:.4f},"
            f"{r['t_collective']:.4f},{r['bottleneck']},"
            f"{r['useful_flops_ratio']:.3f},{r['mfu_bound']:.4f}")
    return rows


def pick_hillclimb_candidates(recs: List[dict]) -> Dict[str, dict]:
    """The three §Perf targets: worst roofline fraction, most collective-
    bound, most representative (largest collective *count* — the cell that
    stresses the paper's synchronization scheduling the hardest)."""
    single = [r for r in recs if r["mesh"] == "16x16"]
    if not single:
        return {}
    worst_mfu = min(
        (r for r in single if r["shape"].startswith("train")),
        key=lambda r: r["mfu_bound"])
    most_coll = max(
        single, key=lambda r: r["t_collective"] /
        max(r["t_compute"] + r["t_memory"] + r["t_collective"], 1e-12))
    most_sync = max(
        single,
        key=lambda r: sum(c["count"] for c in r["collectives"].values()))
    return {"worst_roofline": worst_mfu, "most_collective_bound": most_coll,
            "most_sync_ops": most_sync}


def main() -> List[str]:
    recs = load_records()
    if not recs:
        return ["roofline_report,0.0,no_artifacts_found_run_dryrun_first"]
    out = []
    for line in render_table(recs, mesh=None):
        out.append(f"roofline,{0.0:.1f},{line}")
    cands = pick_hillclimb_candidates(recs)
    for k, r in cands.items():
        out.append(f"roofline_candidate_{k},{0.0:.1f},"
                   f"{r['arch']}/{r['shape']} bottleneck={r['bottleneck']}")
    return out


if __name__ == "__main__":
    for r in main():
        print(r)
