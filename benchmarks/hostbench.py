"""Host-row primitive benchmarks: the paper's Figure-2/3 sweeps with real
threads on this container (measured tier), comparing spin / spin+backoff /
FA mutexes, spin vs sleeping semaphores, XF vs centralized barriers, and
the host-only futex.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, List

from repro.core.abstraction import WaitStrategy
from repro.core.hostsync import (CentralizedBarrier, FutexMutex,
                                 SleepingSemaphore, SpinMutex, SpinSemaphore,
                                 TicketMutex, XFBarrier)


def _run_threads(n: int, fn: Callable[[int], None]) -> float:
    start = threading.Barrier(n + 1)
    done = threading.Barrier(n + 1)

    def runner(tid):
        start.wait()
        fn(tid)
        done.wait()

    ts = [threading.Thread(target=runner, args=(i,), daemon=True)
          for i in range(n)]
    for t in ts:
        t.start()
    start.wait()
    t0 = time.perf_counter()
    done.wait()
    dt = time.perf_counter() - t0
    for t in ts:
        t.join()
    return dt


def bench_mutex(make, threads: int, ops: int) -> float:
    m = make()

    def work(tid):
        for _ in range(ops):
            m.lock()
            m.unlock()

    dt = _run_threads(threads, work)
    return threads * ops / dt


def bench_semaphore(make, threads: int, ops: int) -> float:
    s = make()

    def work(tid):
        for _ in range(ops):
            s.wait()
            s.post()

    dt = _run_threads(threads, work)
    return threads * ops / dt


def bench_barrier(make, threads: int, ops: int) -> float:
    b = make(threads)

    def work(tid):
        for _ in range(ops):
            b.arrive_and_wait(tid)

    dt = _run_threads(threads, work)
    return ops / dt


def main(threads: int = 8, ops: int = 300) -> List[str]:
    rows: List[str] = []

    cases = [
        ("host_mutex_spin", lambda: SpinMutex(WaitStrategy.SPIN)),
        ("host_mutex_spin_backoff", lambda: SpinMutex(WaitStrategy.SPIN_BACKOFF)),
        ("host_mutex_fa", lambda: TicketMutex()),
        ("host_mutex_futex", lambda: FutexMutex()),
    ]
    for name, make in cases:
        t0 = time.perf_counter()
        ops_s = bench_mutex(make, threads, ops)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(f"{name}_t{threads},{us:.1f},ops_per_s={ops_s:.0f}")

    for init in (1, 4):
        for name, make in (
                ("host_sem_spin", lambda i=init: SpinSemaphore(i)),
                ("host_sem_sleeping", lambda i=init: SleepingSemaphore(i))):
            t0 = time.perf_counter()
            ops_s = bench_semaphore(make, threads, ops)
            us = (time.perf_counter() - t0) * 1e6
            rows.append(f"{name}{init}_t{threads},{us:.1f},ops_per_s={ops_s:.0f}")

    for name, make in (("host_barrier_xf", XFBarrier),
                       ("host_barrier_centralized", CentralizedBarrier)):
        t0 = time.perf_counter()
        ops_s = bench_barrier(make, threads, max(ops // 4, 25))
        us = (time.perf_counter() - t0) * 1e6
        rows.append(f"{name}_t{threads},{us:.1f},barriers_per_s={ops_s:.0f}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
