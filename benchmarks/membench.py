"""Paper Tables 1-3: memory-system benchmarks.

Three tiers, labeled in the output:
  simulated — the discrete-event simulator parameterized from Table 1
              replaying the paper's 12 benchmarks on the Tesla/Fermi
              abstractions (the self-consistency check: 8/12 cells within
              a few %, deviations discussed in EXPERIMENTS.md);
  measured  — the same benchmark grid run with real threads on this host
              (the 'Host' machine-abstraction row);
  interpret — the Pallas membench kernel semantics check (timings under
              interpret mode are not hardware times).
"""

from __future__ import annotations

import time
from typing import List

from repro.core.abstraction import FERMI, TESLA, MachineAbstraction
from repro.core.hostbench_probe import classify_host
from repro.core.memsim import run_membench

PAPER_TABLE1 = {
    # (machine, contentious, atomic, preceded) read/write ms per 1000 acc.
    "tesla": {
        ("vol", "cont"): (0.848, 0.829),
        ("vol", "nonc"): (0.590, 0.226),
        ("atm", "cont"): (78.407, 78.404),
        ("atm", "nonc"): (0.845, 0.991),
        ("vpa", "cont"): (0.923, 0.915),
        ("vpa", "nonc"): (0.601, 0.228),
    },
    "fermi": {
        ("vol", "cont"): (0.494, 0.175),
        ("vol", "nonc"): (0.043, 0.029),
        ("atm", "cont"): (1.479, 1.470),
        ("atm", "nonc"): (0.437, 0.312),
        ("vpa", "cont"): (1.473, 0.824),
        ("vpa", "nonc"): (0.125, 0.050),
    },
}


def run_sim_table1(accesses: int = 200) -> List[str]:
    rows = []
    for m, name in ((TESLA, "tesla"), (FERMI, "fermi")):
        for (kind, cont), (p_read, p_write) in PAPER_TABLE1[name].items():
            atomic = kind == "atm"
            preceded = kind == "vpa"
            for write, paper in ((False, p_read), (True, p_write)):
                t0 = time.perf_counter()
                sim = run_membench(
                    m, atomic=atomic, contentious=(cont == "cont"),
                    write=write, preceded_by_atomic=preceded,
                    accesses=accesses)
                us = (time.perf_counter() - t0) * 1e6
                rows.append(
                    f"membench_sim_{name}_{kind}_{cont}_"
                    f"{'w' if write else 'r'},{us:.1f},"
                    f"sim_ms={sim:.3f};paper_ms={paper:.3f};"
                    f"ratio={sim / paper:.2f}")
    return rows


def run_host_row(threads: int = 8, accesses: int = 5000) -> List[str]:
    t0 = time.perf_counter()
    host = classify_host(threads=threads, accesses=accesses)
    us = (time.perf_counter() - t0) * 1e6
    s = host.summary()
    return [
        f"membench_host_classify,{us:.1f},"
        f"P1={s['P1_atomic_volatile_ratio']:.1f};"
        f"P2={s['P2_contention_ratio']:.2f};"
        f"P3={int(s['P3_line_hostage'])}"
    ]


def run_table2_table3() -> List[str]:
    rows = []
    for m, name in ((TESLA, "tesla"), (FERMI, "fermi")):
        t0 = time.perf_counter()
        # Table 2: contentious:noncontentious; Table 3: x:volatile
        cv = run_membench(m, atomic=False, contentious=True, write=False, accesses=200)
        nv = run_membench(m, atomic=False, contentious=False, write=False, accesses=200)
        ca = run_membench(m, atomic=True, contentious=True, write=False, accesses=200)
        na = run_membench(m, atomic=True, contentious=False, write=False, accesses=200)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(f"membench_ratios_{name},{us:.1f},"
                    f"T2_vol={cv / nv:.2f};T2_atm={ca / na:.2f};"
                    f"T3_cont={ca / cv:.2f};T3_nonc={na / nv:.2f}")
    return rows


def main() -> List[str]:
    rows = run_sim_table1()
    rows += run_table2_table3()
    rows += run_host_row()
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
