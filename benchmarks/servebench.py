"""Serving benchmark: legacy per-request loop vs slot-pool batching.

Measures tokens/s and queue-wait percentiles (p50/p99) under Poisson
arrivals at several concurrency budgets K, for

  * ``legacy``  — the old per-request Python decode loop (sequential),
  * ``slots``   — the semaphore-gated continuous-batching slot engine,
  * ``paged``   — the same engine on the block-table page arena
    (serve/kv_pages.py): equal arena bytes, mutex-gated page
    allocator on the admission/retire hot path. Paged rows run lazy
    growth by default and ALWAYS measure the eager (PR 3 worst-case
    reservation) baseline alongside on the same trace: token streams
    must match, and the row reports the allocator lock ledger —
    ``lock_acquires_per_token`` plus its drop vs the eager run's
    one-acquire-per-page accounting (``lock_drop_vs_pr3_per_page``,
    the tentpole acceptance number),

plus the Algorithm-5 kernel-planned wait percentiles for the same trace,
so the predicted and measured timelines can be compared. ``--kv-layout``
selects which engine rows to measure (CI runs both).

A dedicated **shared-prefix trace** (``paged_prefix`` rows) measures
copy-on-write prefix sharing (DESIGN.md §11): groups of requests repeat
a live prompt with arrivals staggered one round apart, and the paged
engine runs with ``--prefix-sharing on`` and ``off`` on the identical
trace. Token streams must match bit-for-bit; the ``on`` row must
allocate strictly fewer physical pages (``pages_per_token`` — prefix
pages become increfs) at no increase in ``lock_acquires_per_token``
(refcount traffic rides the existing batched critical sections). CI
asserts both deltas.

A dedicated **interleaved-arrivals trace** (``interleaved`` rows)
measures continuous chunked prefill (DESIGN.md §12): long prompts and
short decodes arrive interleaved on a page-tight arena, and the paged
engine runs the identical trace with ``prefill_chunk_tokens`` set
(chunked) and unset (one-shot). Token streams must match bit-for-bit;
chunked admission — bookkeeping plus the first chunk's page, instead of
a whole padded bucket — must cut the p99 queue wait, at no increase in
``lock_acquires_per_token`` (chunk page demand folds into the existing
per-round top-up batch) and a strictly lower prefill pad fraction. CI
asserts all four deltas.

An **open-loop front-end trace** (``BENCH_frontend.json``) drives the
same engine through the asyncio front-end (serve/frontend.py, DESIGN.md
§13): concurrent clients arrive Poisson on the wall clock, stream
tokens as rounds complete, and every ``--cancel-every``-th client hangs
up mid-generation. The trace reports p50/p99 time-to-first-token,
goodput under the ``--slo-ms`` TTFT SLO, and the cancellation-safety
ledger CI gates on: zero leaked pages after the drain (refcount-safe
with shared prefixes) and survivors' token streams bit-identical to the
closed-loop driver on the same prompts.

  PYTHONPATH=src python benchmarks/servebench.py --smoke

``--smoke`` runs a reduced sweep and writes ``BENCH_serve.json`` so CI
records the perf trajectory.
"""

from __future__ import annotations

import argparse
import asyncio
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def poisson_arrival_steps(n: int, capacity: int, new_tokens: int,
                          load: float, rng) -> np.ndarray:
    """Arrival step-times for offered load ``load`` (fraction of replica
    token throughput: rate = load * K / service_steps)."""
    rate = load * capacity / float(new_tokens)
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.floor(np.cumsum(gaps)).astype(np.int64)


def shared_prefix_prompts(n: int, prompt_len: int, n_groups: int,
                          vocab: int, rng) -> np.ndarray:
    """The prefix-sharing arrival trace's prompts: ``n_groups`` distinct
    random prompts, each repeated round-robin — every follower's prompt
    is a full-length (page-aligned by construction when prompt_len is a
    page multiple) repeat of a live leader's, the workload shape of
    shared system preambles / few-shot headers."""
    base = rng.integers(0, vocab, (n_groups, prompt_len)).astype(np.int32)
    return base[np.arange(n) % n_groups]


def staggered_arrivals(n: int, n_groups: int, decode_chunk: int
                       ) -> np.ndarray:
    """Round-robin waves: one request per group per scheduler round
    (``decode_chunk`` steps). Same-round admissions cannot adopt from
    each other (the donor's pages exist only after its insert), so the
    wave spacing guarantees every follower's admission finds the
    previous member of its group still decoding — a live donor — as
    long as ``n_groups`` leaves slot headroom (the trace runner keeps
    ``n_groups <= capacity / 2``)."""
    return (decode_chunk * (np.arange(n) // n_groups)).astype(np.int64)


def bench_slot_engine(model, params, prompts, arrivals, *, capacity,
                      new_tokens, decode_chunk, seed, kv_layout="slots",
                      page_size=16, page_growth="lazy",
                      allocator_wait=None, prefix_sharing="auto",
                      prefill_chunk_tokens=None, round_token_budget=None,
                      num_pages=None):
    from repro.serve.engine import SlotServeEngine
    # ``prompts`` may be a rectangular [n, L] array or a list of 1-D
    # arrays of different lengths (the interleaved trace mixes long
    # prompts with short ones)
    n = len(prompts)
    prompt_len = max(int(np.asarray(p).size) for p in prompts)
    max_len = prompt_len + new_tokens + 1
    engine = SlotServeEngine(model, params, capacity=capacity,
                             max_len=max_len, decode_chunk=decode_chunk,
                             seed=seed, kv_layout=kv_layout,
                             page_size=page_size, page_growth=page_growth,
                             allocator_wait=allocator_wait,
                             prefix_sharing=prefix_sharing,
                             prefill_chunk_tokens=prefill_chunk_tokens,
                             round_token_budget=round_token_budget,
                             num_pages=num_pages)
    # warm the prefill/decode traces outside the timed region (the
    # longest prompt compiles both chunked-round traces: chunk=C while
    # prefilling, chunk=0 for its pure-decode tail), then reset every
    # counter the report reads (step clock included, so the arrival
    # schedule starts at 0)
    warm = max(prompts, key=lambda p: np.asarray(p).size)
    engine.submit(warm, max_new_tokens=min(2, new_tokens))
    engine.run_until_done()
    engine.finished.clear()
    engine.grant_log.clear()
    engine.decode_dispatches = 0
    engine.step_clock = 0
    engine.pauses = engine.preemptions = 0
    engine.prefix_hits = engine.shared_pages_adopted = 0
    engine.cow_splits = 0
    engine.prefill_tokens = engine.pad_tokens = 0
    engine.prefill_chunks = 0
    engine.decode_rounds_stalled_by_prefill = 0
    engine.admission.admitted = engine.admission.completed = 0
    if kv_layout == "paged":
        engine.pool.pages.reset_stats()

    t0 = time.perf_counter()
    nxt = 0
    while nxt < n or engine.queue or engine.active:
        while nxt < n and arrivals[nxt] <= engine.step_clock:
            engine.submit(prompts[nxt], new_tokens)
            nxt += 1
        if engine.step() == 0 and not engine.queue and nxt < n:
            engine.step_clock += 1          # idle tick until next arrival
    dt = time.perf_counter() - t0
    st = engine.stats()
    fifo_ok = engine.grant_log == sorted(engine.grant_log)
    row = {
        "tokens": int(st["tokens"]),
        "wall_s": dt,
        "tok_per_s": st["tokens"] / dt,
        "p50_wait_steps": st["p50_wait_steps"],
        "p99_wait_steps": st["p99_wait_steps"],
        "p50_wait_s": st["p50_wait_s"],
        "p99_wait_s": st["p99_wait_s"],
        "decode_dispatches": int(st["decode_dispatches"]),
        "fifo_ok": bool(fifo_ok),
        # chunked-prefill ledger (one-shot rows report it too: their
        # pad tokens are the bucket padding chunking exists to shed)
        "prefill_chunk_tokens": int(st["prefill_chunk_tokens"]),
        "prefill_tokens": int(st["prefill_tokens"]),
        "pad_tokens": int(st["pad_tokens"]),
        "pad_fraction": float(st["pad_fraction"]),
        "prefill_chunks": int(st["prefill_chunks"]),
        "decode_rounds_stalled_by_prefill": int(
            st["decode_rounds_stalled_by_prefill"]),
    }
    streams = {r.rid: list(r.out_tokens) for r in engine.finished}
    if kv_layout == "paged":
        engine.pool.check()                  # leak-free after the drain
        row.update({
            "page_size": page_size,
            "page_growth": engine.page_growth,
            "allocator_wait": engine.pool.pages.wait_mode,
            "wait_strategy": engine.pool.pages.wait_strategy.value,
            "pages_total": int(st["pages_total"]),
            "pages_peak_in_use": int(st["pages_peak_in_use"]),
            "page_allocs": int(st["page_allocs"]),
            "page_frees": int(st["page_frees"]),
            "page_pauses": int(st["page_pauses"]),
            "page_preemptions": int(st["page_preemptions"]),
            "lock_acquires": int(st["lock_acquires"]),
            "lock_contended_acquires": int(st["lock_contended_acquires"]),
            "lock_held_s": float(st["lock_held_s"]),
            "lock_acquires_per_token": float(st["lock_acquires_per_token"]),
            # the PR 3 "per-page" accounting the acceptance criterion
            # benchmarks against: one lock acquisition per page moved
            "per_page_lock_acquires_per_token": float(
                st["per_page_lock_acquires_per_token"]),
            # prefix sharing's ledger (DESIGN.md §11)
            "prefix_sharing": bool(engine.prefix_sharing),
            "pages_alloced": int(st["pages_alloced"]),
            "pages_per_token": float(st["pages_per_token"]),
            "prefix_hits": int(st["prefix_hits"]),
            "shared_pages_adopted": int(st["shared_pages_adopted"]),
            "cow_splits": int(st["cow_splits"]),
        })
    return row, streams


def bench_open_loop(model, params, prompts, closed_streams, *, capacity,
                    new_tokens, decode_chunk, seed, page_size,
                    prefix_sharing, prefill_chunk_tokens, cancel_every,
                    cancel_after_tokens, arrival_rate, slo_ms,
                    intake_limit=256):
    """Open-loop trace through the asyncio front-end: Poisson wall-clock
    arrivals, token streaming, every ``cancel_every``-th client hanging
    up after ``cancel_after_tokens`` streamed tokens.

    ``closed_streams`` are the closed-loop driver's per-prompt greedy
    streams on the identical engine config; survivors must match them
    bit-for-bit (greedy streams depend only on the prompt, so neither
    arrival timing nor other clients' cancellations may show through).
    """
    from repro.serve.engine import RequestState, SlotServeEngine
    from repro.serve.frontend import AsyncFrontend, IntakeFullError

    n = len(prompts)
    prompt_len = max(int(np.asarray(p).size) for p in prompts)
    max_len = prompt_len + new_tokens + 1
    engine = SlotServeEngine(model, params, capacity=capacity,
                             max_len=max_len, decode_chunk=decode_chunk,
                             seed=seed, kv_layout="paged",
                             page_size=page_size,
                             prefix_sharing=prefix_sharing,
                             prefill_chunk_tokens=prefill_chunk_tokens)
    # warm the compiled traces so TTFT measures scheduling, not jit
    warm = max(prompts, key=lambda p: np.asarray(p).size)
    engine.submit(warm, max_new_tokens=min(2, new_tokens))
    engine.run_until_done()
    engine.finished.clear()
    engine.grant_log.clear()
    engine.decode_dispatches = 0
    engine.step_clock = 0
    engine.cancellations = engine.expiries = 0
    engine.pool.pages.reset_stats()

    rng = np.random.default_rng(seed + 3)
    gaps_s = rng.exponential(1.0 / arrival_rate, n)
    cancels = {i for i in range(n)
               if cancel_every and i % cancel_every == cancel_every - 1}
    records = []

    async def client(fe, i, prompt):
        rec = {"i": i, "tokens": [], "handle": None, "shed": False}
        records.append(rec)
        try:
            h = await fe.submit(prompt, new_tokens)
        except IntakeFullError:
            rec["shed"] = True
            return
        rec["handle"] = h
        async for tok in h:
            rec["tokens"].append(tok)

    # hang up once the client has its tokens-in-hand quota. Driving the
    # cancel from the between-rounds hook (rather than the consumer
    # coroutine) makes it deterministic: generations run >= 4 rounds and
    # the quota is reached by round 1-2, so every cancel lands while its
    # request is still mid-flight — what the leak gate must exercise.
    async def hook(fe):
        for rec in records:
            h = rec["handle"]
            if (h is not None and rec["i"] in cancels
                    and h._streamed >= cancel_after_tokens
                    and not h._cancel_requested):
                h.cancel()

    async def drive():
        async with AsyncFrontend(engine, intake_limit=intake_limit,
                                 round_hook=hook) as fe:
            tasks = []
            for i, prompt in enumerate(prompts):
                await asyncio.sleep(gaps_s[i])
                tasks.append(asyncio.ensure_future(client(fe, i, prompt)))
            await asyncio.gather(*tasks)
            await fe.drain()
            return fe

    t0 = time.perf_counter()
    fe = asyncio.run(drive())
    wall_s = time.perf_counter() - t0

    # cancellation safety: the drained arena must be exactly full again
    engine.pool.pages.check()
    leaked = engine.pool.pages.num_pages - engine.pool.pages.n_free

    # survivors = clients that never asked to cancel (a cancelling
    # client that lost the race to natural completion stops consuming
    # its stream, so its local token list is truncated by design)
    survivors_match = all(
        rec["tokens"] == closed_streams[rec["i"]]
        for rec in records
        if rec["i"] not in cancels
        and rec["handle"] is not None
        and rec["handle"].state is RequestState.FINISHED)
    ttfts = sorted(r["handle"].ttft_s for r in records
                   if r["handle"] is not None
                   and r["handle"].ttft_s is not None)
    slo_s = slo_ms / 1e3
    good_tokens = sum(
        len(r["tokens"]) for r in records
        if r["handle"] is not None
        and r["handle"].state is RequestState.FINISHED
        and r["handle"].ttft_s is not None
        and r["handle"].ttft_s <= slo_s)
    st = fe.stats()
    return {
        "requests": n,
        "capacity": capacity,
        "arrival_rate": arrival_rate,
        "cancel_every": cancel_every,
        "wall_s": wall_s,
        "rounds": int(st["frontend_rounds"]),
        "finished": int(st["finished"]),
        "cancelled": int(st["cancelled"]),
        "expired": int(st["expired"]),
        "shed": int(st["frontend_shed"]),
        "tokens": int(st["tokens"]),
        "tok_per_s": st["tokens"] / wall_s,
        "goodput_tok_per_s": good_tokens / wall_s,
        "slo_ms": slo_ms,
        "slo_attainment": (len([t for t in ttfts if t <= slo_s])
                           / max(len(ttfts), 1)),
        "ttft_p50_ms": (1e3 * float(np.median(ttfts)) if ttfts
                        else float("nan")),
        "ttft_p99_ms": (1e3 * float(np.percentile(ttfts, 99)) if ttfts
                        else float("nan")),
        "p99_queued_steps": float(st["p99_queued_steps"]),
        "p99_prefill_steps": float(st["p99_prefill_steps"]),
        "p99_decode_steps": float(st["p99_decode_steps"]),
        "prefix_hits": int(st["prefix_hits"]),
        "leaked_pages": int(leaked),
        "survivor_streams_match_closed_loop": bool(survivors_match),
        "fifo_ok": bool(engine.grant_log == sorted(engine.grant_log)),
    }


def bench_legacy(model, params, prompts, *, new_tokens):
    from repro.serve.engine import ServeEngine
    n, prompt_len = prompts.shape
    max_len = prompt_len + new_tokens + 1
    engine = ServeEngine(model, params, max_len=max_len)
    engine.generate({"tokens": jnp.asarray(prompts[0])[None, :]}, 2)  # warm

    t0 = time.perf_counter()
    waits, tokens = [], 0
    for i in range(n):
        waits.append(time.perf_counter() - t0)   # all arrive at t=0
        out = engine.generate(
            {"tokens": jnp.asarray(prompts[i])[None, :]}, new_tokens)
        tokens += int(out.tokens.size)
    dt = time.perf_counter() - t0
    return {
        "tokens": tokens,
        "wall_s": dt,
        "tok_per_s": tokens / dt,
        "p50_wait_s": float(np.median(waits)),
        "p99_wait_s": float(np.percentile(waits, 99)),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--capacities", type=int, nargs="+",
                    default=[1, 4, 8])
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--decode-chunk", type=int, default=4)
    ap.add_argument("--kv-layout", default="both",
                    choices=("slots", "paged", "both"),
                    help="which KV arena layout(s) to measure")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--page-growth", default="lazy",
                    choices=("lazy", "eager"),
                    help="paged-layout reservation policy for the main "
                         "paged rows (the eager baseline is always "
                         "measured alongside for the lock-traffic drop)")
    ap.add_argument("--allocator-wait", default=None,
                    choices=("auto", "spin", "spin_backoff",
                             "sleeping", "adaptive"),
                    help="pin the page allocator's wait strategy "
                         "(default: select_impl's choice)")
    ap.add_argument("--prefix-sharing", default="both",
                    choices=("on", "off", "both"),
                    help="which sharing modes the dedicated "
                         "shared-prefix trace measures (paged layout "
                         "only; 'both' adds the on-vs-off deltas the CI "
                         "gate asserts)")
    ap.add_argument("--prefix-groups", type=int, default=4,
                    help="distinct prompts in the shared-prefix trace "
                         "(every other request repeats one of them)")
    ap.add_argument("--chunked-prefill", default="both",
                    choices=("on", "off", "both"),
                    help="which prefill schedules the dedicated "
                         "interleaved-arrivals trace measures (paged "
                         "layout only; 'both' adds the chunked-vs-"
                         "one-shot deltas the CI gate asserts)")
    ap.add_argument("--interleaved-long-len", type=int, default=None,
                    help="long-prompt length for the interleaved trace "
                         "(default 5 pages; shorts are one page)")
    ap.add_argument("--load", type=float, default=0.8)
    ap.add_argument("--open-loop", default="on", choices=("on", "off"),
                    help="run the open-loop front-end trace (Poisson "
                         "wall-clock arrivals + mid-flight "
                         "cancellations through serve/frontend.py)")
    ap.add_argument("--arrival-rate", type=float, default=50.0,
                    help="open-loop trace: mean wall-clock arrival "
                         "rate, requests/s")
    ap.add_argument("--cancel-every", type=int, default=3,
                    help="open-loop trace: every Nth client cancels "
                         "mid-generation (0 = nobody cancels)")
    ap.add_argument("--cancel-after-tokens", type=int, default=2,
                    help="open-loop trace: tokens a cancelling client "
                         "consumes before hanging up")
    ap.add_argument("--slo-ms", type=float, default=30000.0,
                    help="open-loop trace: TTFT SLO for the goodput "
                         "split (generous by default — CPU smoke "
                         "rounds are slow; tighten on hardware)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--frontend-out", default="BENCH_frontend.json",
                    help="where the open-loop trace's report lands")
    args = ap.parse_args(argv)

    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve.scheduler import plan_admission

    cfg = get_arch(args.arch)
    cfg = cfg.reduced()  # this bench targets the CPU smoke tier
    if args.smoke:
        args.requests = min(args.requests, 16)
        args.capacities = [1, 4]
        # oversubscribe slightly so admission/retire batches fill up and
        # the steady-state (not the ramp/drain tails) dominates the
        # lock-traffic accounting
        args.load = max(args.load, 1.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)

    legacy = bench_legacy(model, params, prompts,
                          new_tokens=args.new_tokens)
    print(f"legacy_loop,tok_per_s={legacy['tok_per_s']:.1f},"
          f"p50_wait_s={legacy['p50_wait_s']:.2f},"
          f"p99_wait_s={legacy['p99_wait_s']:.2f}")

    layouts = (("slots", "paged") if args.kv_layout == "both"
               else (args.kv_layout,))
    rows = {"arch": cfg.name, "requests": args.requests,
            "prompt_len": args.prompt_len, "new_tokens": args.new_tokens,
            "decode_chunk": args.decode_chunk, "load": args.load,
            "page_size": args.page_size, "legacy": legacy}
    rows.update({layout: {} for layout in layouts})
    if "paged" in layouts:
        rows["paged_eager"] = {}
    for k in args.capacities:
        arrivals = poisson_arrival_steps(
            args.requests, k, args.new_tokens, args.load, rng)
        plan = plan_admission(arrivals.astype(np.float32),
                              np.full(args.requests, float(args.new_tokens),
                                      np.float32), k)
        for layout in layouts:
            got, streams = bench_slot_engine(
                model, params, prompts, arrivals, capacity=k,
                new_tokens=args.new_tokens, decode_chunk=args.decode_chunk,
                seed=args.seed, kv_layout=layout, page_size=args.page_size,
                page_growth=args.page_growth,
                allocator_wait=args.allocator_wait)
            got["plan_p50_wait_steps"] = plan.p50_wait
            got["plan_p99_wait_steps"] = plan.p99_wait
            got["speedup_vs_legacy"] = got["tok_per_s"] / legacy["tok_per_s"]
            extra = ""
            if layout == "paged":
                # the eager (PR 3 reservation) baseline on the same
                # trace: token streams must match and the lock-traffic
                # drop is the tentpole's acceptance number; when the
                # main rows are already pinned eager, reuse them
                # instead of re-running the identical configuration
                if args.page_growth == "eager":
                    eag, eag_streams = dict(got), streams
                else:
                    eag, eag_streams = bench_slot_engine(
                        model, params, prompts, arrivals, capacity=k,
                        new_tokens=args.new_tokens,
                        decode_chunk=args.decode_chunk, seed=args.seed,
                        kv_layout="paged", page_size=args.page_size,
                        page_growth="eager",
                        allocator_wait=args.allocator_wait)
                eag["plan_p50_wait_steps"] = plan.p50_wait
                eag["plan_p99_wait_steps"] = plan.p99_wait
                eag["speedup_vs_legacy"] = (eag["tok_per_s"]
                                            / legacy["tok_per_s"])
                rows["paged_eager"][str(k)] = eag
                got["eager_lazy_tokens_match"] = bool(streams == eag_streams)
                lat = got["lock_acquires_per_token"]
                got["lock_drop_vs_eager"] = (
                    eag["lock_acquires_per_token"] / lat if lat else
                    float("inf"))
                # the PR 3 baseline the acceptance criterion names:
                # worst-case reservation at insert, one lock acquisition
                # per page moved — i.e. the eager run's per-page ledger
                got["lock_drop_vs_pr3_per_page"] = (
                    eag["per_page_lock_acquires_per_token"] / lat if lat
                    else float("inf"))
                extra = (f",pages_peak={got['pages_peak_in_use']}"
                         f"/{got['pages_total']},"
                         f"growth={got['page_growth']},"
                         f"lock_per_tok={lat:.4f},"
                         f"drop_vs_eager={got['lock_drop_vs_eager']:.2f}x,"
                         f"drop_vs_pr3_per_page="
                         f"{got['lock_drop_vs_pr3_per_page']:.2f}x,"
                         f"tokens_match={got['eager_lazy_tokens_match']}")
            rows[layout][str(k)] = got
            print(f"{layout}_engine_K{k},tok_per_s={got['tok_per_s']:.1f},"
                  f"p50_wait_steps={got['p50_wait_steps']:.1f},"
                  f"p99_wait_steps={got['p99_wait_steps']:.1f},"
                  f"plan_p50={got['plan_p50_wait_steps']:.1f},"
                  f"plan_p99={got['plan_p99_wait_steps']:.1f},"
                  f"speedup={got['speedup_vs_legacy']:.2f}x,"
                  f"fifo_ok={got['fifo_ok']}{extra}")

    # ---- dedicated shared-prefix trace (prefix sharing on vs off) ----
    # Every follower repeats a live leader's prompt, arrivals staggered
    # one round apart so the prefix index is warm at each admission.
    # Sharing must not change a single token (greedy bit-identity);
    # what it changes is the page ledger: prefix pages become increfs.
    if "paged" in layouts and args.kv_layout != "slots":
        k = max(args.capacities)
        # half the slots serve leaders, half followers: every wave's
        # admission finds the previous member of its group still live
        n_groups = max(1, min(args.prefix_groups, k // 2, args.requests))
        # an unaligned prompt length puts the prompt's tail in a partial
        # page, so every adoption ends in a real CoW split at the
        # follower's first generated token — the trace exercises the
        # whole §11 protocol, not just boundary adoption
        sp_prompt_len = args.prompt_len + (args.prompt_len % args.page_size
                                           == 0)
        # long enough generation that page demand arises *mid-flight*
        # (past the prefill bucket's grant): the off-run then pays grow
        # acquires for pages the on-run never allocates, which is where
        # sharing's lock story shows up — splits fold into grow rounds
        sp_new_tokens = max(3 * args.new_tokens, 2 * args.decode_chunk)
        sp_prompts = shared_prefix_prompts(
            args.requests, sp_prompt_len, n_groups, cfg.vocab_size,
            np.random.default_rng(args.seed + 1))
        sp_arrivals = staggered_arrivals(args.requests, n_groups,
                                         args.decode_chunk)
        modes = (("on", "off") if args.prefix_sharing == "both"
                 else (args.prefix_sharing,))
        sp_rows, sp_streams = {}, {}
        for mode in modes:
            got, streams = bench_slot_engine(
                model, params, sp_prompts, sp_arrivals, capacity=k,
                new_tokens=sp_new_tokens, decode_chunk=args.decode_chunk,
                seed=args.seed, kv_layout="paged",
                page_size=args.page_size, page_growth=args.page_growth,
                allocator_wait=args.allocator_wait, prefix_sharing=mode)
            sp_rows[mode] = got
            sp_streams[mode] = streams
        if len(modes) == 2:
            on, off = sp_rows["on"], sp_rows["off"]
            on["tokens_match_off"] = bool(
                sp_streams["on"] == sp_streams["off"])
            on["pages_drop_vs_off"] = (
                off["pages_per_token"] / on["pages_per_token"]
                if on["pages_per_token"] else float("inf"))
            on["lock_ratio_vs_off"] = (
                on["lock_acquires_per_token"]
                / off["lock_acquires_per_token"]
                if off["lock_acquires_per_token"] else float("inf"))
        rows["paged_prefix"] = {"capacity": k, "groups": n_groups,
                                **sp_rows}
        for mode in modes:
            r = sp_rows[mode]
            extra = ""
            if mode == "on" and "pages_drop_vs_off" in r:
                extra = (f",pages_drop_vs_off={r['pages_drop_vs_off']:.2f}x,"
                         f"lock_ratio_vs_off={r['lock_ratio_vs_off']:.2f},"
                         f"tokens_match={r['tokens_match_off']}")
            print(f"paged_prefix_{mode}_K{k},"
                  f"tok_per_s={r['tok_per_s']:.1f},"
                  f"pages_per_token={r['pages_per_token']:.3f},"
                  f"lock_per_tok={r['lock_acquires_per_token']:.4f},"
                  f"prefix_hits={r['prefix_hits']},"
                  f"shared_pages={r['shared_pages_adopted']},"
                  f"cow_splits={r['cow_splits']}{extra}")

    # ---- interleaved-arrivals trace (chunked vs one-shot prefill) ----
    # Long prompts and short decodes arrive interleaved on a page-tight
    # arena: the workload where a whole-prompt prefill at admission both
    # stalls the in-flight decodes for a full dispatch and must afford
    # its entire padded bucket in pages before it can be granted.
    # Chunked admission is bookkeeping (slot + first chunk's page) and
    # the prompt prefills C tokens per round *inside* the decode
    # dispatch, so grants land rounds earlier; the CI gate asserts the
    # p99 queue-wait drop at bit-identical token streams with
    # lock_acquires_per_token not increased.
    if "paged" in layouts and args.kv_layout != "slots":
        k = max(args.capacities)
        il_long = (args.interleaved_long_len
                   if args.interleaved_long_len else 5 * args.page_size)
        il_short = args.page_size
        # two pages per chunk: few enough prefill rounds that chunked
        # admissions/retirements batch as tightly as one-shot's (lock
        # parity), small enough that a long prompt still spreads over
        # several rounds (the interleaving under test)
        il_chunk = 2 * args.page_size
        # decode long enough that slot turnover is decode-dominated in
        # both modes — the regime chunking targets (prefill hidden
        # inside decode rounds), and what keeps per-token lock traffic
        # comparable between the two schedules
        il_new = 2 * args.new_tokens
        rng_il = np.random.default_rng(args.seed + 2)
        il_prompts = [
            rng_il.integers(0, cfg.vocab_size,
                            il_long if i % 2 == 0 else il_short
                            ).astype(np.int32)
            for i in range(args.requests)]
        il_arrivals = poisson_arrival_steps(
            args.requests, k, il_new, max(args.load, 1.2), rng_il)
        # 7/8 of the all-slots worst case: mild page pressure — enough
        # that admission sizing matters (the one-shot path must afford
        # whole padded buckets), not so starved that chunked admission
        # falls to drip-feed single-page grants every round
        il_pages = (7 * k * ((il_long + il_new + 1 + args.page_size - 1)
                             // args.page_size)) // 8
        modes = (("chunked", "unchunked") if args.chunked_prefill == "both"
                 else (("chunked",) if args.chunked_prefill == "on"
                       else ("unchunked",)))
        il_rows, il_streams = {}, {}
        for mode in modes:
            got, streams = bench_slot_engine(
                model, params, il_prompts, il_arrivals, capacity=k,
                new_tokens=il_new, decode_chunk=args.decode_chunk,
                seed=args.seed, kv_layout="paged",
                page_size=args.page_size, page_growth=args.page_growth,
                allocator_wait=args.allocator_wait,
                num_pages=il_pages,
                prefill_chunk_tokens=(il_chunk if mode == "chunked"
                                      else None))
            il_rows[mode] = got
            il_streams[mode] = streams
        if len(modes) == 2:
            ch, un = il_rows["chunked"], il_rows["unchunked"]
            ch["tokens_match_unchunked"] = bool(
                il_streams["chunked"] == il_streams["unchunked"])
            # the latency gate is wall-clock: the step clock never
            # charges one-shot mode for its whole-prompt prefill
            # dispatches (they run inside admission, between rounds),
            # which is exactly the cost chunking removes
            ch["p99_wait_s_drop_vs_unchunked"] = (
                un["p99_wait_s"] / ch["p99_wait_s"]
                if ch["p99_wait_s"] else float("inf"))
            ch["lock_ratio_vs_unchunked"] = (
                ch["lock_acquires_per_token"]
                / un["lock_acquires_per_token"]
                if un["lock_acquires_per_token"] else float("inf"))
            ch["pad_fraction_unchunked"] = un["pad_fraction"]
        rows["interleaved"] = {"capacity": k, "long_len": il_long,
                               "short_len": il_short,
                               "chunk_tokens": il_chunk,
                               "num_pages": il_pages, **il_rows}
        for mode in modes:
            r = il_rows[mode]
            extra = ""
            if mode == "chunked" and "tokens_match_unchunked" in r:
                extra = (f",p99_s_drop="
                         f"{r['p99_wait_s_drop_vs_unchunked']:.2f}x,"
                         f"lock_ratio={r['lock_ratio_vs_unchunked']:.2f},"
                         f"tokens_match={r['tokens_match_unchunked']}")
            print(f"interleaved_{mode}_K{k},"
                  f"tok_per_s={r['tok_per_s']:.1f},"
                  f"p99_wait_s={r['p99_wait_s']:.3f},"
                  f"p99_wait_steps={r['p99_wait_steps']:.1f},"
                  f"pad_fraction={r['pad_fraction']:.3f},"
                  f"lock_per_tok={r['lock_acquires_per_token']:.4f},"
                  f"prefill_chunks={r['prefill_chunks']},"
                  f"stalled_rounds="
                  f"{r['decode_rounds_stalled_by_prefill']}{extra}")

    # ---- open-loop front-end trace (asyncio lifecycle, cancellations)
    # Shared-prefix prompts at capacity, arriving Poisson on the wall
    # clock through the asyncio front-end; every Nth client hangs up
    # mid-stream. The closed-loop driver on the identical engine config
    # supplies the reference streams: survivors must match bit-for-bit,
    # and the drained arena must hold zero leaked pages even though
    # cancelled requests shared refcounted prefix pages with survivors.
    if args.open_loop == "on" and "paged" in layouts:
        k = max(args.capacities)
        ol_groups = max(1, min(args.prefix_groups, k // 2,
                               args.requests))
        ol_prompts = shared_prefix_prompts(
            args.requests, args.prompt_len, ol_groups, cfg.vocab_size,
            np.random.default_rng(args.seed + 4))
        ol_chunk = args.page_size
        # enough decode rounds (>= 4) that a client consuming tokens as
        # they stream can cancel while its request is still in flight —
        # a 2-round generation finishes before any cancel can land
        ol_new = max(4 * args.decode_chunk, args.new_tokens)
        closed, closed_streams = bench_slot_engine(
            model, params, ol_prompts, np.zeros(args.requests),
            capacity=k, new_tokens=ol_new,
            decode_chunk=args.decode_chunk, seed=args.seed,
            kv_layout="paged", page_size=args.page_size,
            prefix_sharing="on", prefill_chunk_tokens=ol_chunk)
        # streams are keyed by rid in submission order (the warm-up
        # request holds the lowest rid and was cleared from finished)
        ordered = [closed_streams[r] for r in sorted(closed_streams)]
        fe_row = bench_open_loop(
            model, params, list(ol_prompts), ordered, capacity=k,
            new_tokens=ol_new, decode_chunk=args.decode_chunk,
            seed=args.seed, page_size=args.page_size,
            prefix_sharing="on", prefill_chunk_tokens=ol_chunk,
            cancel_every=args.cancel_every,
            cancel_after_tokens=args.cancel_after_tokens,
            arrival_rate=args.arrival_rate, slo_ms=args.slo_ms)
        fe_row["closed_loop_tok_per_s"] = closed["tok_per_s"]
        rows["frontend"] = fe_row
        print(f"frontend_open_loop_K{k},"
              f"tok_per_s={fe_row['tok_per_s']:.1f},"
              f"goodput_tok_per_s={fe_row['goodput_tok_per_s']:.1f},"
              f"ttft_p50_ms={fe_row['ttft_p50_ms']:.0f},"
              f"ttft_p99_ms={fe_row['ttft_p99_ms']:.0f},"
              f"slo_attainment={fe_row['slo_attainment']:.2f},"
              f"cancelled={fe_row['cancelled']},"
              f"shed={fe_row['shed']},"
              f"leaked_pages={fe_row['leaked_pages']},"
              f"survivors_match="
              f"{fe_row['survivor_streams_match_closed_loop']},"
              f"fifo_ok={fe_row['fifo_ok']}")
        if args.frontend_out:
            with open(args.frontend_out, "w") as f:
                json.dump(fe_row, f, indent=2)
            print(f"# wrote {args.frontend_out}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {args.out}")

    batched = [v for layout in layouts
               for kk, v in rows[layout].items() if int(kk) > 1]
    if batched and not all(v["speedup_vs_legacy"] > 1.0 for v in batched):
        print("# WARNING: batched engine not faster than legacy at K > 1")
    return rows


if __name__ == "__main__":
    main()
