"""Pallas kernel micro-benchmarks (interpret-mode semantics + wall time).

Interpret-mode wall times are Python-evaluator times, NOT hardware times —
they are recorded to track kernel-logic regressions, and each row also
re-validates the kernel against its pure-jnp oracle.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.membench.ops import make_buffer, membench
from repro.kernels.membench.ref import membench_ref
from repro.kernels.semaphore.ops import semaphore_admission
from repro.kernels.semaphore.ref import sleeping_semaphore_ref
from repro.kernels.ticket_lock.ops import ticket_lock_run
from repro.kernels.ticket_lock.ref import ticket_lock_ref
from repro.kernels.xf_barrier.ops import fresh_flags, xf_barrier
from repro.kernels.xf_barrier.ref import xf_barrier_ref


def main() -> List[str]:
    rows: List[str] = []
    key = jax.random.PRNGKey(0)

    # ---- xf_barrier
    n = 64
    ones = jnp.ones(n, jnp.int32)
    t0 = time.perf_counter()
    k = xf_barrier(fresh_flags(n), jnp.int32(1), ones, ones)
    jax.block_until_ready(k)
    us = (time.perf_counter() - t0) * 1e6
    r = xf_barrier_ref(fresh_flags(n), jnp.int32(1), ones, ones)
    ok = all(np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(k, r))
    rows.append(f"kernel_xf_barrier_n{n},{us:.1f},match={int(ok)}")

    # ---- ticket_lock
    arr = jax.random.permutation(key, jnp.arange(n, dtype=jnp.int32))
    m = jax.random.uniform(key, (n,), minval=0.5, maxval=1.5)
    b = jax.random.normal(key, (n,))
    t0 = time.perf_counter()
    g1, t1, a1 = ticket_lock_run(arr, m, b)
    jax.block_until_ready(a1)
    us = (time.perf_counter() - t0) * 1e6
    g2, t2, a2 = ticket_lock_ref(arr, m, b)
    ok = (np.array_equal(np.asarray(g1), np.asarray(g2))
          and abs(float(a1) - float(a2)) < 1e-3)
    rows.append(f"kernel_ticket_lock_n{n},{us:.1f},match={int(ok)};fifo=1")

    # ---- semaphore admission
    at = jnp.sort(jax.random.uniform(key, (n,)) * 10)
    hold = jax.random.uniform(key, (n,), minval=0.1, maxval=2.0)
    t0 = time.perf_counter()
    gk, rk, wk = semaphore_admission(at, hold, capacity=4)
    jax.block_until_ready(gk)
    us = (time.perf_counter() - t0) * 1e6
    gr, rr, wr = sleeping_semaphore_ref(at, hold, 4)
    ok = np.allclose(np.asarray(gk), np.asarray(gr), rtol=1e-6)
    rows.append(f"kernel_semaphore_n{n}_k4,{us:.1f},match={int(ok)}")

    # ---- membench (4 cells)
    for cont in (True, False):
        for wr2 in (True, False):
            buf = make_buffer(16)
            t0 = time.perf_counter()
            bk, sk = membench(buf, n_steps=16, contentious=cont, write=wr2,
                              repeats=8)
            jax.block_until_ready(sk)
            us = (time.perf_counter() - t0) * 1e6
            br, sr = membench_ref(buf, 16, contentious=cont, write=wr2,
                                  repeats=8)
            ok = np.allclose(np.asarray(bk), np.asarray(br))
            rows.append(
                f"kernel_membench_{'c' if cont else 'n'}"
                f"{'w' if wr2 else 'r'},{us:.1f},match={int(ok)}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
