"""Pallas kernel micro-benchmarks (interpret-mode semantics + wall time).

Interpret-mode wall times are Python-evaluator times, NOT hardware times —
they are recorded to track kernel-logic regressions, and each row also
re-validates the kernel against its pure-jnp oracle.

Each kernel family emits one machine-readable record (family, config,
wall time, oracle match); ``main()`` keeps the legacy CSV lines for
``benchmarks.run``, and running this module directly also writes the
records to ``BENCH_kernels.json`` — the CI artifact the kernel gate
reads (every record's ``match`` must be true).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.membench.ops import make_buffer, membench
from repro.kernels.membench.ref import membench_ref
from repro.kernels.paged_attention.kernel import fused_paged_decode
from repro.kernels.paged_attention.ref import paged_decode_ref
from repro.kernels.semaphore.ops import semaphore_admission
from repro.kernels.semaphore.ref import sleeping_semaphore_ref
from repro.kernels.ticket_lock.ops import ticket_lock_run
from repro.kernels.ticket_lock.ref import ticket_lock_ref
from repro.kernels.xf_barrier.ops import fresh_flags, xf_barrier
from repro.kernels.xf_barrier.ref import xf_barrier_ref

Record = Dict[str, object]


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) * 1e6


def bench_xf_barrier(n: int = 64) -> Record:
    ones = jnp.ones(n, jnp.int32)
    k, us = _timed(lambda: xf_barrier(fresh_flags(n), jnp.int32(1),
                                      ones, ones))
    r = xf_barrier_ref(fresh_flags(n), jnp.int32(1), ones, ones)
    ok = all(np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(k, r))
    return {"family": "xf_barrier", "name": f"kernel_xf_barrier_n{n}",
            "n": n, "us": us, "match": bool(ok)}


def bench_ticket_lock(n: int = 64) -> Record:
    key = jax.random.PRNGKey(0)
    arr = jax.random.permutation(key, jnp.arange(n, dtype=jnp.int32))
    m = jax.random.uniform(key, (n,), minval=0.5, maxval=1.5)
    b = jax.random.normal(key, (n,))
    (g1, t1, a1), us = _timed(lambda: ticket_lock_run(arr, m, b))
    g2, t2, a2 = ticket_lock_ref(arr, m, b)
    ok = (np.array_equal(np.asarray(g1), np.asarray(g2))
          and abs(float(a1) - float(a2)) < 1e-3)
    return {"family": "ticket_lock", "name": f"kernel_ticket_lock_n{n}",
            "n": n, "us": us, "match": bool(ok), "fifo": True}


def bench_semaphore(n: int = 64, capacity: int = 4) -> Record:
    key = jax.random.PRNGKey(0)
    at = jnp.sort(jax.random.uniform(key, (n,)) * 10)
    hold = jax.random.uniform(key, (n,), minval=0.1, maxval=2.0)
    (gk, rk, wk), us = _timed(
        lambda: semaphore_admission(at, hold, capacity=capacity))
    gr, rr, wr = sleeping_semaphore_ref(at, hold, capacity)
    ok = np.allclose(np.asarray(gk), np.asarray(gr), rtol=1e-6)
    return {"family": "semaphore",
            "name": f"kernel_semaphore_n{n}_k{capacity}",
            "n": n, "capacity": capacity, "us": us, "match": bool(ok)}


def bench_membench() -> List[Record]:
    out = []
    for cont in (True, False):
        for wr in (True, False):
            buf = make_buffer(16)
            (bk, sk), us = _timed(
                lambda: membench(buf, n_steps=16, contentious=cont,
                                 write=wr, repeats=8))
            br, sr = membench_ref(buf, 16, contentious=cont, write=wr,
                                  repeats=8)
            ok = np.allclose(np.asarray(bk), np.asarray(br))
            tag = f"{'c' if cont else 'n'}{'w' if wr else 'r'}"
            out.append({"family": "membench",
                        "name": f"kernel_membench_{tag}",
                        "contentious": cont, "write": wr,
                        "us": us, "match": bool(ok)})
    return out


def bench_paged_attention() -> List[Record]:
    """The fused paged-decode kernel (DESIGN.md §16) against its
    pure-jnp oracle: a GQA cell and an MHA cell, both with ragged
    lengths, a sentinel-tail table, and one fully-masked row."""
    out = []
    for tag, kv, g, ps in (("gqa4", 2, 4, 4), ("mha", 4, 1, 8)):
        b, hd, num_pages, p_cap = 4, 16, 24, 4
        rng = np.random.default_rng(17)
        q = jnp.asarray(rng.standard_normal((b, kv, g, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((num_pages, ps, kv, hd)),
                        jnp.float32)
        v = jnp.asarray(rng.standard_normal((num_pages, ps, kv, hd)),
                        jnp.float32)
        lens = rng.integers(1, p_cap * ps + 1, size=b)
        pages = np.full((b, p_cap), num_pages, np.int32)
        for i in range(b - 1):               # last row stays fully masked
            need = -(-int(lens[i]) // ps)
            pages[i, :need] = rng.choice(num_pages, size=need,
                                         replace=False)
        pages_j = jnp.asarray(pages)
        lens_j = jnp.asarray(lens, jnp.int32)
        got, us = _timed(lambda: fused_paged_decode(
            q, k, v, pages_j, lens_j, interpret=True))
        want = paged_decode_ref(q, k, v, pages_j, lens_j)
        ok = bool(np.allclose(np.asarray(got), np.asarray(want),
                              atol=1e-5, rtol=1e-5))
        out.append({"family": "paged_attention",
                    "name": f"kernel_paged_attention_{tag}",
                    "batch": b, "kv_heads": kv, "gqa_group": g,
                    "head_dim": hd, "page_size": ps,
                    "num_pages": num_pages, "table_width": p_cap,
                    "us": us, "match": ok})
    return out


def records() -> List[Record]:
    out = [bench_xf_barrier(), bench_ticket_lock(), bench_semaphore()]
    out += bench_membench()
    out += bench_paged_attention()
    return out


def _legacy_line(r: Record) -> str:
    extra = ";fifo=1" if r.get("fifo") else ""
    return f"{r['name']},{r['us']:.1f},match={int(bool(r['match']))}{extra}"


def main() -> List[str]:
    """benchmarks.run entry point: legacy CSV lines."""
    return [_legacy_line(r) for r in records()]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="machine-readable per-family records (the CI "
                         "kernel-gate artifact); '' skips the write")
    args = ap.parse_args()
    recs = records()
    for r in recs:
        print(_legacy_line(r))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=2)
        print(f"# wrote {args.out}")
