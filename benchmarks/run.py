"""Benchmark entry point — one section per paper table/figure + system rows.

Prints ``name,us_per_call,derived`` CSV lines:

  membench_*    paper Tables 1-3 (simulated Tesla/Fermi + measured host)
  fig1/2/3_*    paper Figures 1-3 (primitive ops/s vs concurrency)
  table5_*      best-implementation auto-selection vs the paper's Table 5
  headline_*    paper Section-7 headline speedups
  host_*        real-thread host-row sweeps
  kernel_*      Pallas kernel checks (interpret tier)
  roofline*     the 40-cell dry-run roofline table (artifacts required)

Usage: PYTHONPATH=src python -m benchmarks.run [--fast] [--section NAME]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sweeps (CI mode)")
    ap.add_argument("--section", default=None,
                    choices=("membench", "primitives", "hostbench",
                             "kernels", "roofline"))
    args = ap.parse_args()

    t_start = time.time()
    sections = []
    if args.section in (None, "membench"):
        from benchmarks import membench
        sections.append(("membench", membench.main))
    if args.section in (None, "primitives"):
        from benchmarks import primitives
        sections.append(("primitives", lambda: primitives.main(fast=args.fast)))
    if args.section in (None, "hostbench"):
        from benchmarks import hostbench
        sections.append(("hostbench", lambda: hostbench.main(
            threads=4 if args.fast else 8, ops=100 if args.fast else 300)))
    if args.section in (None, "kernels"):
        from benchmarks import kernelbench
        sections.append(("kernels", kernelbench.main))
    if args.section in (None, "roofline"):
        from benchmarks import roofline_report
        sections.append(("roofline", roofline_report.main))

    print("name,us_per_call,derived")
    for name, fn in sections:
        t0 = time.time()
        try:
            for row in fn():
                print(row)
        except Exception as e:  # pragma: no cover
            print(f"{name}_SECTION_FAILED,0.0,{e!r}", file=sys.stderr)
            raise
        print(f"# section {name} took {time.time() - t0:.1f}s",
              file=sys.stderr)
    print(f"# total {time.time() - t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
