"""Batched serving example: continuous batching + semaphore admission.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "qwen3-14b", "--smoke", "--requests", "12",
          "--capacity", "4", "--prompt-len", "16", "--new-tokens", "8"])
