"""Slot-pool continuous batching example: N > K requests, FIFO-verified.

Round-trips 12 concurrent requests through a 4-slot engine — the
Algorithm-5 sleeping semaphore gates admission, the Pallas semaphore
kernel plans each round's batch, and one fixed-shape batched decode
serves all active slots per dispatch.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main

if __name__ == "__main__":
    engine = main(["--arch", "qwen3-14b", "--smoke", "--requests", "12",
                   "--capacity", "4", "--prompt-len", "16",
                   "--new-tokens", "8", "--legacy"])
    # N > K round-trip: every request finished, grants in arrival order
    assert len(engine.finished) == 12
    assert engine.grant_log == sorted(engine.grant_log), engine.grant_log
    assert all(len(r.out_tokens) == 8 for r in engine.finished)
    print("[example] 12 requests over 4 slots: FIFO grant order verified")
