"""Slot-pool continuous batching example: N > K requests, FIFO-verified.

Round-trips 12 concurrent requests through a 4-slot engine — the
Algorithm-5 sleeping semaphore gates admission, the Pallas semaphore
kernel plans each round's batch, and one fixed-shape batched decode
serves all active slots per dispatch.

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --kv-layout paged

With ``--kv-layout paged`` the engine runs on the block-table page arena
(serve/kv_pages.py) and, after the round-trip, proves the layout's
point: at *equal arena bytes* it serves one context longer than the
contiguous layout's ``max_len``, with tokens identical to the legacy
per-request loop — and then the copy-on-write demo: two requests with
the *same prompt* served with ``--prefix-sharing on`` allocate fewer
total pages than with it off (the second request adopts the first's
prefix pages read-only and splits only at its first divergent write),
while emitting bit-identical token streams either way. DESIGN.md §11.
"""

import argparse

import numpy as np

from repro.launch.serve import main

DEFAULTS = ["--arch", "qwen3-14b", "--smoke", "--requests", "12",
            "--capacity", "4", "--prompt-len", "16", "--new-tokens", "8",
            "--legacy"]

if __name__ == "__main__":
    # only the layout knobs are overridable — the asserts below pin the
    # fixed 12-request workload
    ap = argparse.ArgumentParser()
    ap.add_argument("--kv-layout", default="slots",
                    choices=("slots", "paged"))
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--page-growth", default="lazy",
                    choices=("lazy", "eager"))
    ap.add_argument("--prefix-sharing", default="auto",
                    choices=("auto", "on", "off"))
    ex = ap.parse_args()
    argv = DEFAULTS + ["--kv-layout", ex.kv_layout,
                       "--page-size", str(ex.page_size),
                       "--page-growth", ex.page_growth,
                       "--prefix-sharing", ex.prefix_sharing]
    engine = main(argv)
    # N > K round-trip: every request finished, grants in arrival order
    assert len(engine.finished) == 12
    assert engine.grant_log == sorted(engine.grant_log), engine.grant_log
    assert all(len(r.out_tokens) == 8 for r in engine.finished)
    print("[example] 12 requests over 4 slots: FIFO grant order verified")

    if engine.kv_layout == "paged":
        import jax.numpy as jnp

        from repro.serve.engine import ServeEngine, SlotServeEngine

        engine.pool.check()                    # no page leaks after drain
        # Same arena bytes as the contiguous layout (K * max_len tokens),
        # one request almost twice as long as a slot row.
        max_len = engine.max_len
        long_len = 2 * max_len - 6
        prompt = np.asarray(
            np.random.default_rng(7).integers(1, 100, 12), np.int32)
        new_tokens = long_len - prompt.size
        paged = SlotServeEngine(
            engine.model, engine.params, capacity=4, max_len=max_len,
            kv_layout="paged", page_size=ex.page_size, decode_chunk=2,
            page_growth=ex.page_growth)
        req = paged.submit(prompt, max_new_tokens=new_tokens)
        paged.run_until_done(max_rounds=200)
        assert len(req.out_tokens) == new_tokens
        paged.pool.check()
        if ex.page_growth == "lazy":
            # the long context grew page by page: more allocation grants
            # than the single eager reservation, one lock acquire each
            assert paged.pool.pages.allocs > 1, "lazy growth never grew"
        legacy = ServeEngine(engine.model, engine.params, max_len=long_len + 1)
        want = legacy.generate(
            {"tokens": jnp.asarray(prompt)[None, :]}, new_tokens)
        assert req.out_tokens == np.asarray(want.tokens)[0].tolist()
        print(f"[example] paged arena served a {long_len}-token context "
              f"in a max_len={max_len} arena "
              f"(tokens match the legacy loop)")

        # --- copy-on-write prefix sharing: two same-prompt requests ---
        # The second request arrives after the first's prefill landed,
        # so admission finds the whole prompt in the prefix index and
        # adopts its pages (increfs, zero allocations for the prefix);
        # its first generated token write triggers exactly the CoW
        # split. Off re-allocates and re-scatters everything. The token
        # streams must agree bit-for-bit. The demo pins page_size=4 so
        # the 13-token prompt spans 3 full pages + a partial one: the
        # full pages are the net saving (the partial page's adoption is
        # repaid by the split copy — sharing pays off from the second
        # page of common prefix onward).
        demo_prompt = np.asarray(
            np.random.default_rng(11).integers(1, 100, 13), np.int32)

        def run_pair(mode):
            eng = SlotServeEngine(
                engine.model, engine.params, capacity=4, max_len=max_len,
                kv_layout="paged", page_size=4, decode_chunk=2,
                page_growth=ex.page_growth, prefix_sharing=mode)
            first = eng.submit(demo_prompt, max_new_tokens=6)
            eng.step()                       # leader inserts + decodes
            second = eng.submit(demo_prompt.copy(), max_new_tokens=6)
            eng.run_until_done(max_rounds=100)
            eng.pool.check()                 # refcounts drained cleanly
            assert eng.pool.pages.in_use == 0
            return eng, first, second

        on, on_a, on_b = run_pair("on")
        off, off_a, off_b = run_pair("off")
        assert on_a.out_tokens == off_a.out_tokens
        assert on_b.out_tokens == off_b.out_tokens
        assert on.prefix_hits >= 1 and on.shared_pages_adopted >= 1
        assert on.pool.pages.pages_alloced < off.pool.pages.pages_alloced
        print(f"[example] prefix sharing: same-prompt pair allocated "
              f"{int(on.pool.pages.pages_alloced)} pages shared vs "
              f"{int(off.pool.pages.pages_alloced)} unshared "
              f"({int(on.shared_pages_adopted)} adopted, "
              f"{int(on.cow_splits)} CoW split(s)); "
              f"token streams identical")
