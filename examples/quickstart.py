"""Quickstart: the paper's sync library + a tiny LM trained for a few steps.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.abstraction import FERMI, TESLA, PrimitiveKind, select_impl
from repro.sync import SyncLibrary
from repro.core.primitives_sim import run_primitive
from repro.models import build_model, make_batch
from repro.configs.base import ShapeConfig
from repro.train import optimizer as opt
from repro.train.train_loop import make_train_step


def sync_primitives_demo():
    print("== machine-abstraction-driven primitive selection (paper Table 5)")
    for machine in (TESLA, FERMI):
        for prim in PrimitiveKind:
            choice = select_impl(machine, prim, semaphore_initial=10)
            print(f"  {machine.name:14s} {prim.value:9s} -> "
                  f"{choice.algorithm:13s} ({choice.strategy.value}) "
                  f"on backend {choice.backend}")

    print("\n== simulated ops/sec at 64 blocks (Tesla abstraction)")
    for impl in ("spin", "fa"):
        r = run_primitive(TESLA, "mutex", impl, blocks=64, ops=10)
        print(f"  mutex/{impl:4s}: {r.ops_per_sec:12,.0f} ops/s "
              f"(atomics used: {r.atomic_ops})")

    print("\n== real host primitives (threading)")
    lib = SyncLibrary(machine=FERMI)
    m = lib.mutex()
    with m:
        print(f"  acquired a {type(m).__name__} and released it")


def tiny_training_demo():
    print("\n== 10 training steps of a reduced qwen3 config on CPU")
    cfg = get_arch("qwen3-14b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    state = opt.init(ocfg, params)
    step = jax.jit(make_train_step(model, ocfg))
    shape = ShapeConfig("demo", seq_len=32, global_batch=4, mode="train")
    for i in range(10):
        batch = make_batch(cfg, shape, jax.random.PRNGKey(i))
        params, state, metrics = step(params, state, batch)
        if i % 3 == 0 or i == 9:
            print(f"  step {i}: loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    sync_primitives_demo()
    tiny_training_demo()
    print("\nquickstart done.")
