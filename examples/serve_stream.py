"""Async streaming client demo: two concurrent streams, one cancelled.

The minimal open-loop lifecycle (serve/frontend.py, DESIGN.md §13):
two clients submit concurrently through the asyncio front-end and
consume tokens as decode rounds complete; the second client hangs up
after three tokens. The cancelled request's slot and pages are
reclaimed at the next round boundary through the engine's existing
retire path — the demo proves the arena is exactly full again after
the drain — while the surviving stream is untouched.

    PYTHONPATH=src python examples/serve_stream.py
"""

import asyncio

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import AsyncFrontend, RequestState, SlotServeEngine

NEW_TOKENS = 12
CANCEL_AFTER = 3


async def stream(name, handle, cancel_after=None):
    got = []
    async for tok in handle:
        got.append(tok)
        print(f"[{name}] token {len(got):2d}: {tok}")
        if cancel_after is not None and len(got) >= cancel_after:
            print(f"[{name}] hanging up after {len(got)} tokens")
            handle.cancel()
    ttft = (f"TTFT {handle.ttft_s * 1e3:.0f}ms"
            if handle.ttft_s is not None else "no first token")
    print(f"[{name}] stream closed: {handle.state.value}, "
          f"{len(got)} tokens, {ttft}")
    return got


async def main():
    cfg = get_arch("qwen3-14b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (12, 9)]
    engine = SlotServeEngine(
        model, params, capacity=2, max_len=32, decode_chunk=2, seed=0,
        kv_layout="paged", page_size=8, prefill_chunk_tokens=8)

    async with AsyncFrontend(engine, intake_limit=8) as fe:
        a = await fe.submit(prompts[0], NEW_TOKENS)
        b = await fe.submit(prompts[1], NEW_TOKENS)
        got_a, got_b = await asyncio.gather(
            stream("alice", a),
            stream("bob  ", b, cancel_after=CANCEL_AFTER))
        await fe.drain()

    assert a.state is RequestState.FINISHED and len(got_a) == NEW_TOKENS
    assert b.state is RequestState.CANCELLED
    assert CANCEL_AFTER <= len(got_b) < NEW_TOKENS
    engine.pool.pages.check()              # refcount/free-list invariants
    assert engine.pool.pages.n_free == engine.pool.pages.num_pages
    st = engine.stats()
    print(f"[example] {int(st['finished'])} finished, "
          f"{int(st['cancelled'])} cancelled over "
          f"{int(st['decode_dispatches'])} dispatches; page arena "
          f"exactly full again ({engine.pool.pages.n_free}/"
          f"{engine.pool.pages.num_pages} free) — cancellation freed "
          f"every page at the round boundary")


if __name__ == "__main__":
    asyncio.run(main())
