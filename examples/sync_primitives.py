"""The paper's contribution, end to end:

  1. classify machines via the 12-benchmark machine abstraction
     (simulated Tesla/Fermi + this host, measured);
  2. reproduce the headline comparisons (Figures 1-3);
  3. run the paper-derived control plane: an XF barrier detecting a
     straggler, FIFO ticket-mutex membership, semaphore admission.

    PYTHONPATH=src python examples/sync_primitives.py
"""

import threading
import time

import numpy as np

from repro.core.abstraction import FERMI, TESLA, classify
from repro.core.coordinator import ClusterCoordinator
from repro.core.hostbench_probe import classify_host
from repro.core.primitives_sim import run_primitive
from repro.serve.scheduler import plan_admission


def classify_machines():
    print("== machine abstraction (P1 atomic:volatile, P2 contention, P3 hostage)")
    host = classify_host(threads=4, accesses=4000)
    for m in (TESLA, FERMI, host):
        s = m.summary()
        print(f"  {m.name:14s} P1={s['P1_atomic_volatile_ratio']:6.1f} "
              f"P2={s['P2_contention_ratio']:5.2f} "
              f"P3={int(s['P3_line_hostage'])}  class={classify(m)}")


def reproduce_figures():
    print("\n== paper Figure 2 (mutex, 96 blocks):")
    for machine in (TESLA, FERMI):
        row = {}
        for impl in ("spin", "spin_backoff", "fa"):
            r = run_primitive(machine, "mutex", impl, blocks=96, ops=10,
                              max_events=6_000_000)
            row[impl] = r.ops_per_sec
        best = max(row, key=row.get)
        print(f"  {machine.name:14s} " +
              "  ".join(f"{k}={v:,.0f}" for k, v in row.items()) +
              f"  -> best: {best}")


def control_plane_demo():
    print("\n== control plane: straggler detection via XF barrier timeout")
    coord = ClusterCoordinator(world=4, barrier_timeout_s=0.5)

    def healthy(rank):
        coord.heartbeat(rank, 1)
        out = coord.step_barrier(rank)
        if rank == 0 and not out.ok:
            print(f"  rank 0 saw stragglers: {out.stragglers} "
                  f"after {out.wait_s:.2f}s")

    threads = [threading.Thread(target=healthy, args=(r,)) for r in (0, 1, 2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    view = coord.evict(3)
    print(f"  evicted rank 3 -> membership epoch {view.epoch}, "
          f"alive {view.alive}")

    print("\n== serving admission (paper Algorithm 5 as planning kernel)")
    arrivals = np.sort(np.random.default_rng(0).uniform(0, 5, 24)).astype(np.float32)
    service = np.random.default_rng(1).uniform(1, 3, 24).astype(np.float32)
    plan = plan_admission(arrivals, service, capacity=6)
    print(f"  24 requests, capacity 6: p50 wait {plan.p50_wait:.2f}s, "
          f"p99 {plan.p99_wait:.2f}s, makespan {plan.makespan:.1f}s, "
          f"queued {int(plan.waited.sum())}")


if __name__ == "__main__":
    classify_machines()
    reproduce_figures()
    control_plane_demo()
    print("\nsync_primitives demo done.")
