"""The paper's contribution through the unified ``repro.sync`` API:

  1. classify machines via the 12-benchmark machine abstraction
     (simulated Tesla/Fermi + this host, measured once and cached) and
     show the (backend, algorithm, wait-strategy) selection triples;
  2. reproduce the headline comparisons (Figure 2);
  3. plan the *same* primitive trace on three backends — real host
     threads, the Pallas interpret kernel, the pure-jnp oracle — and
     check they agree (the library's portability claim, live);
  4. run the paper-derived control plane: an XF barrier detecting a
     straggler, FIFO ticket-mutex membership, semaphore admission.

    PYTHONPATH=src python examples/sync_primitives.py
"""

import threading

import numpy as np

from repro.core.abstraction import FERMI, TESLA, TPU_V5E, PrimitiveKind, classify
from repro.core.coordinator import ClusterCoordinator
from repro.core.primitives_sim import run_primitive
from repro.serve.scheduler import plan_admission
from repro.sync import SyncLibrary


def classify_machines():
    print("== machine abstraction (P1 atomic:volatile, P2 contention, P3 hostage)")
    # for_host() probes once per process per probe-parameter set
    # (cached; refresh=True re-probes)
    host_lib = SyncLibrary.for_host(threads=4, accesses=4000)
    assert (SyncLibrary.for_host(threads=4, accesses=4000).machine
            is host_lib.machine)  # cache hit
    for m in (TESLA, FERMI, host_lib.machine):
        s = m.summary()
        print(f"  {m.name:14s} P1={s['P1_atomic_volatile_ratio']:6.1f} "
              f"P2={s['P2_contention_ratio']:5.2f} "
              f"P3={int(s['P3_line_hostage'])}  class={classify(m)}")

    print("\n== selection triples (backend, algorithm, wait strategy)")
    for machine in (TESLA, FERMI, TPU_V5E, host_lib.machine):
        lib = SyncLibrary(machine=machine)
        for prim in PrimitiveKind:
            c = lib.choice(prim, semaphore_initial=10)
            print(f"  {machine.name:14s} {prim.value:9s} -> "
                  f"({c.backend:6s}, {c.algorithm:13s}, {c.strategy.value})")
    return host_lib


def reproduce_figures():
    print("\n== paper Figure 2 (mutex, 96 blocks):")
    for machine in (TESLA, FERMI):
        row = {}
        for impl in ("spin", "spin_backoff", "fa"):
            r = run_primitive(machine, "mutex", impl, blocks=96, ops=10,
                              max_events=6_000_000)
            row[impl] = r.ops_per_sec
        best = max(row, key=row.get)
        print(f"  {machine.name:14s} " +
              "  ".join(f"{k}={v:,.0f}" for k, v in row.items()) +
              f"  -> best: {best}")


def cross_backend_check(lib):
    print("\n== one trace, three backends (host threads / Pallas kernel / ref)")
    rng = np.random.default_rng(0)
    arrivals = np.sort(rng.uniform(0, 3, 10)).astype(np.float32)
    holds = rng.uniform(1, 3, 10).astype(np.float32)
    plans = {be: lib.plan_semaphore(arrivals, holds, capacity=3, backend=be)
             for be in ("host", "kernel", "ref")}
    ref = plans["ref"]
    for be, p in plans.items():
        agree = (np.array_equal(p.grant_order, ref.grant_order)
                 and np.allclose(p.release, ref.release, atol=1e-5))
        print(f"  semaphore[{be:6s}] grant_order={p.grant_order.tolist()} "
              f"queued={int(p.waited.sum())} "
              f"{'== ref' if agree else '!= ref  <-- BUG'}")


def control_plane_demo(lib):
    print("\n== control plane: straggler detection via XF barrier timeout")
    coord = ClusterCoordinator(world=4, barrier_timeout_s=0.5)

    def healthy(rank):
        coord.heartbeat(rank, 1)
        out = coord.step_barrier(rank)
        if rank == 0 and not out.ok:
            print(f"  rank 0 saw stragglers: {out.stragglers} "
                  f"after {out.wait_s:.2f}s")

    threads = [threading.Thread(target=healthy, args=(r,)) for r in (0, 1, 2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    view = coord.evict(3)
    print(f"  evicted rank 3 -> membership epoch {view.epoch}, "
          f"alive {view.alive}")

    print("\n== serving admission (paper Algorithm 5 as planning kernel)")
    arrivals = np.sort(np.random.default_rng(0).uniform(0, 5, 24)).astype(np.float32)
    service = np.random.default_rng(1).uniform(1, 3, 24).astype(np.float32)
    plan = plan_admission(arrivals, service, capacity=6, lib=lib)
    print(f"  24 requests, capacity 6 [{plan.backend}]: "
          f"p50 wait {plan.p50_wait:.2f}s, "
          f"p99 {plan.p99_wait:.2f}s, makespan {plan.makespan:.1f}s, "
          f"queued {int(plan.waited.sum())}")


if __name__ == "__main__":
    host_lib = classify_machines()
    reproduce_figures()
    cross_backend_check(host_lib)
    control_plane_demo(host_lib)
    print("\nsync_primitives demo done.")
