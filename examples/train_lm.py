"""End-to-end training driver: a ~100M-param LM for a few hundred steps
with async checkpointing, a mid-run simulated crash, and auto-resume.

CPU-friendly presets (the 100m preset is the deliverable's target size;
25m is the CI-speed default on this single-CPU container — same code
path, smaller widths):

    PYTHONPATH=src python examples/train_lm.py --preset 25m --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.coordinator import ClusterCoordinator
from repro.models import build_model
from repro.models.common import count_params
from repro.train import optimizer as opt
from repro.train.checkpoint import CheckpointManager
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.train_loop import make_train_step

PRESETS = {
    "100m": ArchConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=3072, vocab_size=32768,
        layer_pattern=("attn",), param_dtype="float32"),
    "25m": ArchConfig(
        name="lm-25m", family="dense", num_layers=6, d_model=384,
        num_heads=6, num_kv_heads=2, d_ff=1536, vocab_size=16384,
        layer_pattern=("attn",), param_dtype="float32"),
    "5m": ArchConfig(
        name="lm-5m", family="dense", num_layers=4, d_model=192,
        num_heads=4, num_kv_heads=2, d_ff=768, vocab_size=4096,
        layer_pattern=("attn",), param_dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="25m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate a crash after this step (then rerun "
                    "with --resume)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = build_model(cfg)
    n = count_params(model.spec_tree())
    print(f"[train_lm] {cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.batch}x{args.seq} tokens/step")

    ocfg = opt.AdamWConfig(peak_lr=3e-4, warmup_steps=args.steps // 10,
                           total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, ocfg, num_microbatches=1,
                                      remat=True))
    coord = ClusterCoordinator(world=1)
    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2)

    params = model.init(jax.random.PRNGKey(0))
    state = opt.init(ocfg, params)
    start = 0
    if args.resume:
        latest = ckpt.latest_step()
        if latest is not None:
            tree = ckpt.restore(latest, {"params": params, "m": state.m,
                                         "v": state.v, "count": state.count})
            params, state = tree["params"], opt.AdamWState(
                count=tree["count"], m=tree["m"], v=tree["v"])
            start = latest + 1
            print(f"[train_lm] resumed from step {latest}")

    ds = Prefetcher(SyntheticLM(cfg.vocab_size, args.batch, args.seq,
                                seed=0, start_step=start))
    t0 = time.time()
    try:
        for step in range(start, args.steps):
            raw = next(ds)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            params, state, metrics = step_fn(params, state, batch)
            coord.heartbeat(0, step)
            if step % 20 == 0 or step == args.steps - 1:
                dt = time.time() - t0
                tps = (step - start + 1) * args.batch * args.seq / max(dt, 1e-6)
                print(f"[train_lm] step {step:4d} "
                      f"loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} tok/s {tps:,.0f}")
            if (step + 1) % 50 == 0:
                assert coord.checkpoint_fence(0)
                ckpt.save_async(step, {"params": params, "m": state.m,
                                       "v": state.v, "count": state.count})
            if args.crash_at is not None and step >= args.crash_at:
                ckpt.wait()
                print(f"[train_lm] simulated crash at step {step} "
                      f"(latest checkpoint: {ckpt.latest_step()}); rerun "
                      f"with --resume")
                return
        ckpt.wait()
        assert coord.checkpoint_fence(0)
        ckpt.save(args.steps - 1, {"params": params, "m": state.m,
                                   "v": state.v, "count": state.count})
        print(f"[train_lm] finished {args.steps} steps in "
              f"{time.time() - t0:.0f}s; checkpoints in {args.ckpt_dir}")
    finally:
        ds.close()


if __name__ == "__main__":
    main()
